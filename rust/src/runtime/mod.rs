//! PJRT runtime — loads AOT-compiled model artifacts and executes them on
//! the request path.
//!
//! `aot.py` writes each model as HLO *text* plus a metadata JSON; this
//! module parses the metadata, validates it against the Rust-side feature
//! configuration (so the hot path and the trained model can never
//! disagree on shapes or vocabulary), compiles the HLO once through the
//! PJRT CPU client, and exposes a typed batch-inference call.
//!
//! Python is never involved: after `make artifacts`, the `tao` binary is
//! self-contained.

pub mod artifact;

pub use artifact::{
    artifact_name, write_surrogate_artifact, write_surrogate_artifact_kind, ArtifactMeta,
    ArtifactPool, ModelKind, ModelOutputs, PooledArtifact, Session,
};
