//! Microarchitecture configuration — the paper's Table 3 design space.
//!
//! A [`UarchConfig`] fully determines the detailed model's behaviour:
//! pipeline (fetch width, ROB size), branch predictor algorithm, and the
//! three cache geometries. The three named designs µArch A/B/C used
//! throughout the paper's evaluation are provided as presets, and
//! `crate::dse` enumerates/samples the full space (184,320 designs).

use std::fmt;

/// Branch predictor algorithm choices (Table 3 row "Branch pred.").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PredictorKind {
    /// gem5-style `LocalBP`: PC-indexed table of 2-bit counters.
    Local,
    /// Bi-Mode: two direction-biased PHTs + a choice PHT.
    BiMode,
    /// TAGE-SC-L (structurally faithful, reduced table count; see
    /// `crate::detailed::predictor::TageScL`).
    TageScL,
    /// Alpha 21264-style tournament of local and global predictors.
    Tournament,
}

impl PredictorKind {
    /// All predictor kinds, in Table 3 order.
    pub const ALL: [PredictorKind; 4] = [
        PredictorKind::Local,
        PredictorKind::BiMode,
        PredictorKind::TageScL,
        PredictorKind::Tournament,
    ];

    /// Parse from the names used in configs and CLI flags.
    pub fn parse(s: &str) -> Option<PredictorKind> {
        match s.to_ascii_lowercase().as_str() {
            "local" => Some(PredictorKind::Local),
            "bimode" => Some(PredictorKind::BiMode),
            "tage_sc_l" | "tagescl" | "tage" => Some(PredictorKind::TageScL),
            "tournament" => Some(PredictorKind::Tournament),
            _ => None,
        }
    }

    /// Canonical name (matches the paper's Table 3 spelling).
    pub fn name(self) -> &'static str {
        match self {
            PredictorKind::Local => "Local",
            PredictorKind::BiMode => "BiMode",
            PredictorKind::TageScL => "TAGE_SC_L",
            PredictorKind::Tournament => "Tournament",
        }
    }
}

impl fmt::Display for PredictorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Geometry of one cache (size/associativity; 64-byte lines throughout,
/// as gem5's default).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CacheGeometry {
    /// Total capacity in bytes.
    pub size_bytes: u64,
    /// Set associativity (ways).
    pub assoc: u32,
}

impl CacheGeometry {
    /// Cache line size in bytes (fixed across the design space).
    pub const LINE_BYTES: u64 = 64;

    /// Number of sets.
    pub fn sets(&self) -> u64 {
        self.size_bytes / Self::LINE_BYTES / self.assoc as u64
    }

    /// kB/MB pretty-printer ("32KB", "1MB").
    pub fn size_label(&self) -> String {
        if self.size_bytes >= 1 << 20 {
            format!("{}MB", self.size_bytes >> 20)
        } else {
            format!("{}KB", self.size_bytes >> 10)
        }
    }
}

/// Fixed timing parameters shared across the design space. These mirror
/// the latencies gem5's example ARM O3 configs use; they are not part of
/// Table 3 and stay constant in every experiment.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Timing {
    /// L1 (I or D) hit latency, cycles.
    pub l1_lat: u64,
    /// L2 hit latency, cycles (added on L1 miss).
    pub l2_lat: u64,
    /// Main memory latency, cycles (added on L2 miss).
    pub mem_lat: u64,
    /// Extra cycles on a data-TLB miss (page-walk).
    pub tlb_miss_lat: u64,
    /// Front-end depth: cycles from fetch to earliest issue.
    pub decode_lat: u64,
    /// Minimum branch misprediction redirect penalty, cycles.
    pub mispredict_penalty: u64,
    /// Data TLB entries (fully associative).
    pub dtlb_entries: usize,
}

impl Default for Timing {
    fn default() -> Timing {
        Timing {
            l1_lat: 2,
            l2_lat: 12,
            mem_lat: 90,
            tlb_miss_lat: 20,
            decode_lat: 3,
            mispredict_penalty: 5,
            dtlb_entries: 64,
        }
    }
}

/// A complete microarchitecture design point (one row of Table 3's
/// cartesian product) plus fixed timing.
#[derive(Debug, Clone, PartialEq)]
pub struct UarchConfig {
    /// Design name ("uarch_a", or a generated id for sampled designs).
    pub name: String,
    /// Instructions fetched (and committed) per cycle: 2, 3 or 4.
    pub fetch_width: u32,
    /// Reorder-buffer entries: 32..128.
    pub rob_size: u32,
    /// Branch predictor algorithm.
    pub predictor: PredictorKind,
    /// L1 data cache geometry.
    pub l1d: CacheGeometry,
    /// L1 instruction cache geometry.
    pub l1i: CacheGeometry,
    /// Unified L2 cache geometry.
    pub l2: CacheGeometry,
    /// Fixed latencies.
    pub timing: Timing,
}

impl UarchConfig {
    /// Paper's µArch A: narrow core, small caches, simple predictor.
    pub fn uarch_a() -> UarchConfig {
        UarchConfig {
            name: "uarch_a".into(),
            fetch_width: 2,
            rob_size: 32,
            predictor: PredictorKind::Local,
            l1d: CacheGeometry { size_bytes: 16 << 10, assoc: 2 },
            l1i: CacheGeometry { size_bytes: 8 << 10, assoc: 2 },
            l2: CacheGeometry { size_bytes: 256 << 10, assoc: 2 },
            timing: Timing::default(),
        }
    }

    /// Paper's µArch B: mid-range design.
    pub fn uarch_b() -> UarchConfig {
        UarchConfig {
            name: "uarch_b".into(),
            fetch_width: 3,
            rob_size: 96,
            predictor: PredictorKind::BiMode,
            l1d: CacheGeometry { size_bytes: 32 << 10, assoc: 4 },
            l1i: CacheGeometry { size_bytes: 16 << 10, assoc: 4 },
            l2: CacheGeometry { size_bytes: 1 << 20, assoc: 4 },
            timing: Timing::default(),
        }
    }

    /// Paper's µArch C: wide core, large caches, tournament predictor.
    pub fn uarch_c() -> UarchConfig {
        UarchConfig {
            name: "uarch_c".into(),
            fetch_width: 4,
            rob_size: 128,
            predictor: PredictorKind::Tournament,
            l1d: CacheGeometry { size_bytes: 64 << 10, assoc: 8 },
            l1i: CacheGeometry { size_bytes: 32 << 10, assoc: 8 },
            l2: CacheGeometry { size_bytes: 4 << 20, assoc: 8 },
            timing: Timing::default(),
        }
    }

    /// Look up a named preset.
    pub fn preset(name: &str) -> Option<UarchConfig> {
        match name.to_ascii_lowercase().as_str() {
            "a" | "uarch_a" => Some(Self::uarch_a()),
            "b" | "uarch_b" => Some(Self::uarch_b()),
            "c" | "uarch_c" => Some(Self::uarch_c()),
            _ => None,
        }
    }

    /// One-line summary for logs and reports.
    pub fn summary(&self) -> String {
        format!(
            "{}: fetch={} rob={} bp={} l1d={}x{} l1i={}x{} l2={}x{}",
            self.name,
            self.fetch_width,
            self.rob_size,
            self.predictor,
            self.l1d.size_label(),
            self.l1d.assoc,
            self.l1i.size_label(),
            self.l1i.assoc,
            self.l2.size_label(),
            self.l2.assoc,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table3_columns() {
        let a = UarchConfig::uarch_a();
        assert_eq!(a.fetch_width, 2);
        assert_eq!(a.rob_size, 32);
        assert_eq!(a.predictor, PredictorKind::Local);
        assert_eq!(a.l1d.size_bytes, 16 << 10);
        let b = UarchConfig::uarch_b();
        assert_eq!(b.predictor, PredictorKind::BiMode);
        assert_eq!(b.l2.size_bytes, 1 << 20);
        let c = UarchConfig::uarch_c();
        assert_eq!(c.fetch_width, 4);
        assert_eq!(c.rob_size, 128);
        assert_eq!(c.l1d.assoc, 8);
    }

    #[test]
    fn preset_lookup() {
        assert!(UarchConfig::preset("A").is_some());
        assert!(UarchConfig::preset("uarch_b").is_some());
        assert!(UarchConfig::preset("z").is_none());
    }

    #[test]
    fn cache_geometry_sets() {
        let g = CacheGeometry { size_bytes: 32 << 10, assoc: 4 };
        assert_eq!(g.sets(), 128);
        assert_eq!(g.size_label(), "32KB");
        let g2 = CacheGeometry { size_bytes: 2 << 20, assoc: 8 };
        assert_eq!(g2.size_label(), "2MB");
    }

    #[test]
    fn predictor_parse_round_trip() {
        for p in PredictorKind::ALL {
            assert_eq!(PredictorKind::parse(p.name()), Some(p));
        }
        assert_eq!(PredictorKind::parse("tage"), Some(PredictorKind::TageScL));
        assert_eq!(PredictorKind::parse("nope"), None);
    }

    #[test]
    fn summary_contains_key_fields() {
        let s = UarchConfig::uarch_c().summary();
        assert!(s.contains("fetch=4"));
        assert!(s.contains("Tournament"));
        assert!(s.contains("4MB"));
    }
}
