//! Streaming record access over any trace storage layout.
//!
//! Both consumers of a committed instruction stream — the coordinator's
//! inference engine and the datagen featurization pipeline — iterate
//! records one at a time and never need the whole trace as a slice of
//! any particular layout. [`RecordSource`] is that read surface: AoS
//! record slices, the SoA [`TraceColumns`], and columnar sub-range views
//! all feed the same streaming loops.

use crate::trace::{ColumnsSlice, FuncRecord, TraceColumns};

/// Anything a streaming consumer can pull instructions out of: an AoS
/// record slice or columnar [`TraceColumns`]. `get` assembles the record
/// in registers — implementations must be cheap and allocation-free.
pub trait RecordSource {
    /// Number of instructions.
    fn len(&self) -> usize;
    /// The `i`-th record.
    fn get(&self, i: usize) -> FuncRecord;
    /// True if no instructions.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl RecordSource for [FuncRecord] {
    fn len(&self) -> usize {
        <[FuncRecord]>::len(self)
    }
    #[inline]
    fn get(&self, i: usize) -> FuncRecord {
        self[i]
    }
}

impl RecordSource for TraceColumns {
    fn len(&self) -> usize {
        TraceColumns::len(self)
    }
    #[inline]
    fn get(&self, i: usize) -> FuncRecord {
        self.record(i)
    }
}

impl RecordSource for ColumnsSlice<'_> {
    fn len(&self) -> usize {
        ColumnsSlice::len(self)
    }
    #[inline]
    fn get(&self, i: usize) -> FuncRecord {
        self.record(i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;
    use crate::workloads;

    #[test]
    fn aos_and_soa_sources_agree() {
        let p = workloads::by_name("dee").unwrap().build(3);
        let trace = FunctionalSim::new(&p).run(500);
        let cols = trace.to_columns();
        let aos: &[FuncRecord] = &trace.records;
        assert_eq!(RecordSource::len(aos), cols.len());
        assert!(!RecordSource::is_empty(aos));
        for i in 0..RecordSource::len(aos) {
            assert_eq!(RecordSource::get(aos, i), RecordSource::get(&cols, i));
        }
        let view = cols.slice(100, 200);
        assert_eq!(RecordSource::len(&view), 100);
        assert_eq!(RecordSource::get(&view, 0), trace.records[100]);
    }
}
