//! Pull-based chunked streaming over trace storage.
//!
//! [`RecordSource`](super::source::RecordSource) is the random-access
//! read surface for traces that are already resident. At paper scale the
//! trace never *is* resident: it comes out of a simulator or off disk,
//! hundreds of millions of records long, and the consumers (the
//! inference engine, the datagen featurizer) only ever walk it forward.
//! [`ChunkSource`] is the pull surface for that case: consumers ask for
//! the next bounded [`TraceColumns`] chunk, producers fill it, and the
//! only state that crosses a chunk boundary is whatever the consumer
//! carries (extractor history, window-batcher tail) — the exact warm-up
//! handoff, not an approximation.
//!
//! Three producers cover the pipeline:
//!
//! * [`SliceChunkSource`] — trivial adapter over any in-memory
//!   [`RecordSource`]; keeps existing callers and the byte-identity
//!   oracles working against the streaming paths.
//! * [`FileChunkSource`] — streams the `TAOTFNC1` on-disk format chunk
//!   by chunk (its compressed sibling,
//!   [`CompressedChunkSource`](super::codec::CompressedChunkSource),
//!   streams `TAOTFNC2`; `open_trace_source` in `trace::format` sniffs
//!   the magic and returns whichever fits, and the whole-file
//!   `read_functional_columns` is a thin accumulation loop over that).
//! * the simulator-backed sources (`functional::FuncChunkSource`,
//!   `datagen::SimPairSource`) — generate records on demand so
//!   simulate→featurize→write runs in O(chunk) memory end to end.

use super::columns::TraceColumns;
use super::format::{header_error, read_magic, TraceError, TraceFormat};
use super::serialize::{read_func_body_header, read_func_fields};
use super::source::RecordSource;
use crate::util::fault::panic_message;
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, Read, Seek, SeekFrom};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender};

/// f32 values per record in the context-metric channel (the SimNet
/// baseline's µarch-specific model inputs).
pub const CTX_WIDTH: usize = 6;

/// f32 values per record in the label channel (one `labels.npy` row;
/// `datagen::NUM_LABELS` is pinned to this).
pub const LABEL_WIDTH: usize = 6;

/// A reusable chunk of trace data: the record columns plus the optional
/// per-record side channels a producer carries. Channel presence is
/// all-or-nothing for a given source and constant across its chunks.
#[derive(Debug, Clone, Default)]
pub struct ChunkBuf {
    /// The records, columnar.
    pub cols: TraceColumns,
    /// Context metrics, [`CTX_WIDTH`] per record; empty if the source
    /// carries none.
    pub ctx: Vec<f32>,
    /// Training-label rows, [`LABEL_WIDTH`] per record; empty if the
    /// source carries none.
    pub labels: Vec<f32>,
}

impl ChunkBuf {
    /// Empty buffer.
    pub fn new() -> ChunkBuf {
        ChunkBuf::default()
    }

    /// Records in the chunk.
    pub fn len(&self) -> usize {
        self.cols.len()
    }

    /// True if no records are buffered.
    pub fn is_empty(&self) -> bool {
        self.cols.is_empty()
    }

    /// Drop all records and channel data, keeping allocations.
    pub fn clear(&mut self) {
        self.cols.clear();
        self.ctx.clear();
        self.labels.clear();
    }

    /// True if the chunk carries context metrics.
    pub fn has_ctx(&self) -> bool {
        !self.ctx.is_empty()
    }

    /// True if the chunk carries label rows.
    pub fn has_labels(&self) -> bool {
        !self.labels.is_empty()
    }
}

/// A pull-based producer of bounded trace chunks.
///
/// Contract: `next_chunk` clears `buf` and appends up to `max_rows`
/// records (plus any side channels the source carries, in lockstep);
/// it returns the number appended, `0` meaning the stream is exhausted.
/// `max_rows == 0` is a caller error and must be rejected, not looped
/// on. Sources are forward-only; pulled records are gone.
pub trait ChunkSource {
    /// Records remaining, if the source knows. An upper bound is
    /// allowed (a generator bounded by an instruction budget may halt
    /// early); consumers must treat `0` from `next_chunk` as the truth.
    fn len_hint(&self) -> Option<usize>;

    /// Pull the next chunk into `buf`. See the trait docs for the
    /// contract.
    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize>;

    /// Ground-truth total cycles for label-carrying sources (the
    /// detailed trace's retire clock), available once the stream is
    /// exhausted. `None` for label-free sources or while running.
    fn total_cycles(&self) -> Option<u64> {
        None
    }
}

impl<C: ChunkSource + ?Sized> ChunkSource for &mut C {
    fn len_hint(&self) -> Option<usize> {
        (**self).len_hint()
    }
    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        (**self).next_chunk(buf, max_rows)
    }
    fn total_cycles(&self) -> Option<u64> {
        (**self).total_cycles()
    }
}

impl<C: ChunkSource + ?Sized> ChunkSource for Box<C> {
    fn len_hint(&self) -> Option<usize> {
        (**self).len_hint()
    }
    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        (**self).next_chunk(buf, max_rows)
    }
    fn total_cycles(&self) -> Option<u64> {
        (**self).total_cycles()
    }
}

// ---------------------------------------------------------------------
// In-memory adapter
// ---------------------------------------------------------------------

/// Chunked pull over any in-memory [`RecordSource`], optionally paired
/// with a `[N × 6]` context-metric array. The trivial adapter that lets
/// resident traces feed the streaming consumers (and the oracle for
/// asserting the streamed paths byte-identical to the in-memory ones).
pub struct SliceChunkSource<'a, S: RecordSource + ?Sized> {
    source: &'a S,
    ctx: Option<&'a [f32]>,
    pos: usize,
}

impl<'a, S: RecordSource + ?Sized> SliceChunkSource<'a, S> {
    /// Wrap a record source; `ctx`, when given, must hold
    /// [`CTX_WIDTH`] values per record.
    pub fn new(source: &'a S, ctx: Option<&'a [f32]>) -> Result<SliceChunkSource<'a, S>> {
        if let Some(c) = ctx {
            ensure!(
                c.len() == source.len() * CTX_WIDTH,
                "context metrics: {} values for {} records",
                c.len(),
                source.len()
            );
        }
        Ok(SliceChunkSource { source, ctx, pos: 0 })
    }
}

impl<S: RecordSource + ?Sized> ChunkSource for SliceChunkSource<'_, S> {
    fn len_hint(&self) -> Option<usize> {
        Some(self.source.len() - self.pos)
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        buf.clear();
        let end = (self.pos + max_rows).min(self.source.len());
        for i in self.pos..end {
            buf.cols.push(&self.source.get(i));
        }
        if let Some(c) = self.ctx {
            buf.ctx
                .extend_from_slice(&c[self.pos * CTX_WIDTH..end * CTX_WIDTH]);
        }
        let n = end - self.pos;
        self.pos = end;
        Ok(n)
    }
}

/// Chunked pull over *owned* columns (plus an optional owned
/// `[N × 6]` context-metric array) — the self-contained sibling of
/// [`SliceChunkSource`] for consumers that outlive the scope that built
/// the trace, e.g. a serving job that materializes a functional trace
/// and its detailed SimNet context up front and then streams it from a
/// scheduler thread.
pub struct OwnedChunkSource {
    cols: TraceColumns,
    ctx: Vec<f32>,
    pos: usize,
}

impl OwnedChunkSource {
    /// Take ownership of a trace; `ctx`, when given, must hold
    /// [`CTX_WIDTH`] values per record.
    pub fn new(cols: TraceColumns, ctx: Option<Vec<f32>>) -> Result<OwnedChunkSource> {
        let ctx = ctx.unwrap_or_default();
        if !ctx.is_empty() {
            ensure!(
                ctx.len() == cols.len() * CTX_WIDTH,
                "context metrics: {} values for {} records",
                ctx.len(),
                cols.len()
            );
        }
        Ok(OwnedChunkSource { cols, ctx, pos: 0 })
    }
}

impl ChunkSource for OwnedChunkSource {
    fn len_hint(&self) -> Option<usize> {
        Some(self.cols.len() - self.pos)
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        buf.clear();
        let end = (self.pos + max_rows).min(self.cols.len());
        buf.cols.extend_from(&self.cols, self.pos, end);
        if !self.ctx.is_empty() {
            buf.ctx
                .extend_from_slice(&self.ctx[self.pos * CTX_WIDTH..end * CTX_WIDTH]);
        }
        let n = end - self.pos;
        self.pos = end;
        Ok(n)
    }
}

// ---------------------------------------------------------------------
// File-backed source
// ---------------------------------------------------------------------

/// Streams a `TAOTFNC1` functional-trace file in bounded chunks. The
/// header is validated on open; records are decoded straight into the
/// chunk's columns; a truncated tail, a bad opcode id, a record count
/// that disagrees with the payload, and trailing garbage after the last
/// record all surface as errors, never panics.
pub struct FileChunkSource {
    path: PathBuf,
    name: String,
    reader: BufReader<std::fs::File>,
    declared: usize,
    read: usize,
}

impl FileChunkSource {
    /// Open `path` and validate the `TAOTFNC1` header. A foreign file,
    /// a header cut short, and a trace of the other format are each
    /// refused with a typed [`TraceError`] — never misread.
    pub fn open(path: &Path) -> Result<FileChunkSource> {
        let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut reader = BufReader::new(file);
        let found = read_magic(path, &mut reader)?;
        if found != TraceFormat::V1 {
            return Err(TraceError::WrongFormat {
                path: path.to_path_buf(),
                found,
                expected: TraceFormat::V1,
            }
            .into());
        }
        let (name, declared) =
            read_func_body_header(&mut reader).map_err(|e| header_error(path, e))?;
        let mut src = FileChunkSource {
            path: path.to_path_buf(),
            name,
            reader,
            declared,
            read: 0,
        };
        if declared == 0 {
            src.check_eof()?;
        }
        Ok(src)
    }

    /// Trace name from the header.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Records declared by the header but not yet pulled.
    pub fn remaining(&self) -> usize {
        self.declared - self.read
    }

    /// Reposition so the next pulled record is `row`. `TAOTFNC1`
    /// records are a fixed 27 bytes, so this is pure offset math — no
    /// decode, no scan. `row == declared` positions at end-of-stream;
    /// beyond that is an error.
    pub fn seek_to_row(&mut self, row: u64) -> Result<()> {
        ensure!(
            row <= self.declared as u64,
            "{:?}: seek to row {row} past the {} declared records",
            self.path,
            self.declared
        );
        // magic + name length prefix + name bytes + record count.
        let data_start = (8 + 8 + self.name.len() + 8) as u64;
        self.reader
            .seek(SeekFrom::Start(data_start + row * 27))
            .with_context(|| format!("seek to row {row} in {:?}", self.path))?;
        self.read = row as usize;
        Ok(())
    }

    /// After the declared record count is consumed, the file must end.
    fn check_eof(&mut self) -> Result<()> {
        let mut probe = [0u8; 1];
        match self.reader.read(&mut probe) {
            Ok(0) => Ok(()),
            Ok(_) => bail!(
                "{:?}: trailing bytes after the {} declared records",
                self.path,
                self.declared
            ),
            Err(e) => Err(e).with_context(|| format!("probe EOF in {:?}", self.path)),
        }
    }
}

impl ChunkSource for FileChunkSource {
    fn len_hint(&self) -> Option<usize> {
        Some(self.remaining())
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        buf.clear();
        let n = max_rows.min(self.remaining());
        for k in 0..n {
            let (pc, op, reg_bitmap, mem_addr, mem_bytes, taken) =
                read_func_fields(&mut self.reader).with_context(|| {
                    format!(
                        "{:?}: truncated or corrupt at record {} of {}",
                        self.path,
                        self.read + k,
                        self.declared
                    )
                })?;
            buf.cols
                .push_fields(pc, op, reg_bitmap, mem_addr, mem_bytes, taken);
        }
        self.read += n;
        if n > 0 && self.remaining() == 0 {
            self.check_eof()?;
        }
        Ok(n)
    }
}

// ---------------------------------------------------------------------
// Prefetching puller
// ---------------------------------------------------------------------

/// Runs a [`ChunkSource`] on a scoped side thread, keeping up to
/// `depth` pulled chunks buffered ahead of the consumer, so source I/O
/// (file reads, functional-sim generation) overlaps whatever the
/// consumer does with each chunk — for the pipelined engine paths,
/// both feature staging *and* model execution.
///
/// Buffers recycle through a return channel: steady-state allocation
/// is `depth + 1` [`ChunkBuf`]s regardless of stream length, so the
/// bounded-memory guarantees of the chunked consumers survive the
/// prefetch. Chunks arrive strictly in source order; a source error is
/// delivered once, in order, and ends the stream — exactly the
/// semantics of pulling the source directly.
pub struct ChunkPrefetcher {
    rx: Receiver<Result<ChunkBuf>>,
    recycle: SyncSender<ChunkBuf>,
    done: bool,
}

impl ChunkPrefetcher {
    /// Spawn the prefetch thread inside `scope`, pulling `max_rows`-row
    /// chunks from `source` and running at most `depth` chunks ahead.
    pub fn spawn<'scope, 'env, C>(
        scope: &'scope std::thread::Scope<'scope, 'env>,
        source: &'scope mut C,
        max_rows: usize,
        depth: usize,
    ) -> ChunkPrefetcher
    where
        C: ChunkSource + Send + ?Sized,
    {
        assert!(max_rows >= 1, "zero-length chunk request");
        let depth = depth.max(1);
        let (tx, rx) = sync_channel::<Result<ChunkBuf>>(depth);
        let (recycle, recycle_rx) = sync_channel::<ChunkBuf>(depth + 1);
        scope.spawn(move || {
            let mut spares: Vec<ChunkBuf> = (0..depth + 1).map(|_| ChunkBuf::new()).collect();
            loop {
                let mut buf = match spares.pop() {
                    Some(b) => b,
                    // All buffers are downstream: wait for one to come
                    // back (or for the consumer to hang up).
                    None => match recycle_rx.recv() {
                        Ok(b) => b,
                        Err(_) => return,
                    },
                };
                // A panicking source must not masquerade as clean
                // end-of-stream — `next` below reads a bare producer
                // disconnect as EOF — so the unwind is caught and
                // delivered as the stream's error.
                let pulled = {
                    let _sp = crate::stage_span!("decode");
                    catch_unwind(AssertUnwindSafe(|| source.next_chunk(&mut buf, max_rows)))
                        .unwrap_or_else(|p| {
                            Err(anyhow::anyhow!(
                                "chunk source panicked: {}",
                                panic_message(p.as_ref())
                            ))
                        })
                };
                match pulled {
                    // `next_chunk` cleared the buffer, so an empty buf
                    // is the in-band end-of-stream marker.
                    Ok(0) => {
                        let _ = tx.send(Ok(buf));
                        return;
                    }
                    Ok(n) => {
                        if buf.cols.len() != n {
                            let _ = tx.send(Err(anyhow::anyhow!(
                                "chunk source reported {n} rows but buffered {}",
                                buf.cols.len()
                            )));
                            return;
                        }
                        if tx.send(Ok(buf)).is_err() {
                            return;
                        }
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e));
                        return;
                    }
                }
            }
        });
        ChunkPrefetcher { rx, recycle, done: false }
    }

    /// The next prefetched chunk, `None` once the stream is exhausted.
    /// Pass consumed chunks back via [`ChunkPrefetcher::recycle`] to
    /// keep the buffer pool bounded.
    pub fn next(&mut self) -> Result<Option<ChunkBuf>> {
        if self.done {
            return Ok(None);
        }
        match self.rx.recv() {
            Ok(Ok(buf)) => {
                if buf.is_empty() {
                    self.done = true;
                    Ok(None)
                } else {
                    Ok(Some(buf))
                }
            }
            Ok(Err(e)) => {
                self.done = true;
                Err(e)
            }
            // The producer only exits after sending its end marker or
            // error; a bare disconnect means the scope is unwinding.
            Err(_) => {
                self.done = true;
                Ok(None)
            }
        }
    }

    /// Return a consumed chunk's buffer to the prefetch thread.
    pub fn recycle(&mut self, buf: ChunkBuf) {
        // After end-of-stream the producer is gone; dropping the
        // buffer then is fine.
        let _ = self.recycle.send(buf);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;
    use crate::trace::{read_functional_columns, write_functional_columns};
    use crate::workloads;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tao-chunk-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(format!("{tag}.trace"))
    }

    fn sample_cols(n: u64) -> TraceColumns {
        let p = workloads::by_name("dee").unwrap().build(3);
        FunctionalSim::new(&p).run(n).to_columns()
    }

    #[test]
    fn slice_source_streams_whole_trace_in_chunks() {
        let cols = sample_cols(1_000);
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        assert_eq!(src.len_hint(), Some(1_000));
        let mut buf = ChunkBuf::new();
        let mut rebuilt = TraceColumns::new();
        let mut pulls = 0;
        loop {
            let n = src.next_chunk(&mut buf, 137).unwrap();
            if n == 0 {
                break;
            }
            assert!(n <= 137);
            assert!(!buf.has_ctx() && !buf.has_labels());
            rebuilt.extend_from(&buf.cols, 0, n);
            pulls += 1;
        }
        assert_eq!(rebuilt, cols);
        assert_eq!(pulls, 1_000usize.div_ceil(137));
        assert_eq!(src.len_hint(), Some(0));
    }

    #[test]
    fn slice_source_carries_ctx_in_lockstep() {
        let cols = sample_cols(50);
        let ctx: Vec<f32> = (0..50 * CTX_WIDTH).map(|i| i as f32).collect();
        let mut src = SliceChunkSource::new(&cols, Some(&ctx)).unwrap();
        let mut buf = ChunkBuf::new();
        let n = src.next_chunk(&mut buf, 7).unwrap();
        assert_eq!(n, 7);
        assert_eq!(buf.ctx, &ctx[..7 * CTX_WIDTH]);
        let n = src.next_chunk(&mut buf, 7).unwrap();
        assert_eq!(n, 7);
        assert_eq!(buf.ctx, &ctx[7 * CTX_WIDTH..14 * CTX_WIDTH]);
        // Mis-sized ctx is rejected up front.
        assert!(SliceChunkSource::new(&cols, Some(&ctx[..5])).is_err());
    }

    #[test]
    fn owned_source_matches_slice_source() {
        let cols = sample_cols(500);
        let ctx: Vec<f32> = (0..500 * CTX_WIDTH).map(|i| i as f32).collect();
        let mut slice_src = SliceChunkSource::new(&cols, Some(&ctx)).unwrap();
        let mut owned_src = OwnedChunkSource::new(cols.clone(), Some(ctx.clone())).unwrap();
        let (mut a, mut b) = (ChunkBuf::new(), ChunkBuf::new());
        loop {
            let na = slice_src.next_chunk(&mut a, 77).unwrap();
            let nb = owned_src.next_chunk(&mut b, 77).unwrap();
            assert_eq!(na, nb);
            assert_eq!(a.cols, b.cols);
            assert_eq!(a.ctx, b.ctx);
            if na == 0 {
                break;
            }
        }
        // Mis-sized ctx is rejected up front.
        assert!(OwnedChunkSource::new(cols, Some(vec![0.0; 5])).is_err());
    }

    #[test]
    fn zero_length_chunk_request_is_an_error() {
        let cols = sample_cols(10);
        let mut buf = ChunkBuf::new();
        let mut slice_src = SliceChunkSource::new(&cols, None).unwrap();
        assert!(slice_src.next_chunk(&mut buf, 0).is_err());
        let path = tmp("zero");
        write_functional_columns(&path, "z", &cols).unwrap();
        let mut file_src = FileChunkSource::open(&path).unwrap();
        assert!(file_src.next_chunk(&mut buf, 0).is_err());
    }

    #[test]
    fn file_source_matches_whole_file_reader() {
        let cols = sample_cols(2_000);
        let path = tmp("roundtrip");
        write_functional_columns(&path, "dee", &cols).unwrap();
        let mut src = FileChunkSource::open(&path).unwrap();
        assert_eq!(src.name(), "dee");
        assert_eq!(src.remaining(), 2_000);
        let mut buf = ChunkBuf::new();
        let mut rebuilt = TraceColumns::new();
        while src.next_chunk(&mut buf, 333).unwrap() > 0 {
            rebuilt.extend_from(&buf.cols, 0, buf.len());
        }
        assert_eq!(rebuilt, cols);
        let (name, whole) = read_functional_columns(&path).unwrap();
        assert_eq!(name, "dee");
        assert_eq!(whole, cols);
    }

    #[test]
    fn file_source_rejects_corrupt_header() {
        let path = tmp("badmagic");
        let cols = sample_cols(5);
        write_functional_columns(&path, "x", &cols).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] ^= 0xFF;
        std::fs::write(&path, &bytes).unwrap();
        assert!(FileChunkSource::open(&path).is_err());
        // A header cut off mid-name also errors (never panics).
        bytes[0] ^= 0xFF; // restore the magic
        std::fs::write(&path, &bytes[..10]).unwrap();
        assert!(FileChunkSource::open(&path).is_err());
    }

    #[test]
    fn file_source_errors_on_truncated_tail() {
        let path = tmp("trunc");
        let cols = sample_cols(100);
        write_functional_columns(&path, "x", &cols).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 11]).unwrap();
        let mut src = FileChunkSource::open(&path).unwrap();
        let mut buf = ChunkBuf::new();
        // Chunks before the cut stream fine; the one crossing it errors.
        let mut result = Ok(0);
        for _ in 0..10 {
            result = src.next_chunk(&mut buf, 10);
            if result.is_err() {
                break;
            }
        }
        assert!(result.is_err(), "truncated tail must surface as an error");
    }

    #[test]
    fn file_source_errors_on_trailing_garbage() {
        let path = tmp("trailing");
        let cols = sample_cols(20);
        write_functional_columns(&path, "x", &cols).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(b"junk");
        std::fs::write(&path, &bytes).unwrap();
        let mut src = FileChunkSource::open(&path).unwrap();
        let mut buf = ChunkBuf::new();
        let mut result = Ok(0);
        for _ in 0..3 {
            result = src.next_chunk(&mut buf, 10);
            if result.is_err() {
                break;
            }
        }
        assert!(result.is_err(), "trailing garbage must surface as an error");
        // The whole-file reader shares the check.
        assert!(read_functional_columns(&path).is_err());
    }

    #[test]
    fn file_source_empty_trace_is_ok() {
        let path = tmp("empty");
        write_functional_columns(&path, "e", &TraceColumns::new()).unwrap();
        let mut src = FileChunkSource::open(&path).unwrap();
        let mut buf = ChunkBuf::new();
        assert_eq!(src.next_chunk(&mut buf, 8).unwrap(), 0);
        assert_eq!(src.len_hint(), Some(0));
    }

    #[test]
    fn prefetcher_yields_same_chunks_as_direct_pulls() {
        let cols = sample_cols(1_000);
        let ctx: Vec<f32> = (0..1_000 * CTX_WIDTH).map(|i| i as f32 * 0.25).collect();
        // Direct reference pulls.
        let mut direct = SliceChunkSource::new(&cols, Some(&ctx)).unwrap();
        let mut want: Vec<(TraceColumns, Vec<f32>)> = Vec::new();
        let mut buf = ChunkBuf::new();
        while direct.next_chunk(&mut buf, 97).unwrap() > 0 {
            want.push((buf.cols.clone(), buf.ctx.clone()));
        }
        // Prefetched pulls (depth 2 < chunk count, so recycling cycles).
        let mut src = SliceChunkSource::new(&cols, Some(&ctx)).unwrap();
        let got: Vec<(TraceColumns, Vec<f32>)> = std::thread::scope(|scope| {
            let mut pre = ChunkPrefetcher::spawn(scope, &mut src, 97, 2);
            let mut got = Vec::new();
            while let Some(buf) = pre.next().unwrap() {
                got.push((buf.cols.clone(), buf.ctx.clone()));
                pre.recycle(buf);
            }
            // Exhausted streams keep answering None.
            assert!(pre.next().unwrap().is_none());
            got
        });
        assert_eq!(got.len(), want.len());
        assert_eq!(got, want);
    }

    #[test]
    fn prefetcher_surfaces_source_errors_in_order() {
        let path = tmp("pre-trunc");
        let cols = sample_cols(100);
        write_functional_columns(&path, "x", &cols).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 11]).unwrap();
        let mut src = FileChunkSource::open(&path).unwrap();
        std::thread::scope(|scope| {
            let mut pre = ChunkPrefetcher::spawn(scope, &mut src, 10, 2);
            let mut pulled = 0usize;
            let err = loop {
                match pre.next() {
                    Ok(Some(buf)) => {
                        pulled += buf.len();
                        pre.recycle(buf);
                    }
                    Ok(None) => panic!("truncated tail must error, not end the stream"),
                    Err(e) => break e,
                }
            };
            assert!(pulled < 100, "error must arrive before the declared record count");
            assert!(format!("{err:#}").contains("truncated"), "unexpected error: {err:#}");
            // After the error the stream is over.
            assert!(pre.next().unwrap().is_none());
        });
    }

    #[test]
    fn prefetcher_consumer_can_stop_early() {
        // Dropping the prefetcher mid-stream must not deadlock the
        // scope join (the producer notices the hang-up and exits).
        let cols = sample_cols(2_000);
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        std::thread::scope(|scope| {
            let mut pre = ChunkPrefetcher::spawn(scope, &mut src, 64, 2);
            let buf = pre.next().unwrap().expect("first chunk");
            assert_eq!(buf.len(), 64);
            // Drop without recycling or draining.
        });
    }
}
