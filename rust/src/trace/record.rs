//! Trace record types.

use crate::isa::{Opcode, Reg};
use std::fmt;

/// Which level of the data memory hierarchy served an access.
///
/// This is the label space of the paper's "data access level" softmax head
/// (§4.2: "we use a softmax layer for the data access level, as the output
/// can be multiple categories").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AccessLevel {
    /// Not a memory instruction.
    None,
    /// Hit in the L1 data cache.
    L1,
    /// Missed L1, hit in the unified L2.
    L2,
    /// Missed L2, served by main memory.
    Mem,
}

impl AccessLevel {
    /// Stable class index for the softmax head (None=0, L1=1, L2=2, Mem=3).
    pub fn index(self) -> usize {
        match self {
            AccessLevel::None => 0,
            AccessLevel::L1 => 1,
            AccessLevel::L2 => 2,
            AccessLevel::Mem => 3,
        }
    }

    /// Inverse of [`AccessLevel::index`].
    pub fn from_index(i: usize) -> AccessLevel {
        match i {
            0 => AccessLevel::None,
            1 => AccessLevel::L1,
            2 => AccessLevel::L2,
            3 => AccessLevel::Mem,
            _ => panic!("bad access level index {i}"),
        }
    }

    /// Number of classes.
    pub const COUNT: usize = 4;

    /// True if the access missed L1 (the paper's "L1 Dcache miss" MPKI
    /// counts L2 hits and memory accesses).
    pub fn is_l1_miss(self) -> bool {
        matches!(self, AccessLevel::L2 | AccessLevel::Mem)
    }
}

impl fmt::Display for AccessLevel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            AccessLevel::None => "-",
            AccessLevel::L1 => "L1",
            AccessLevel::L2 => "L2",
            AccessLevel::Mem => "MEM",
        };
        write!(f, "{s}")
    }
}

/// One committed instruction in a functional trace. Static properties
/// only — everything here is microarchitecture agnostic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FuncRecord {
    /// Program counter.
    pub pc: u64,
    /// Opcode.
    pub opcode: Opcode,
    /// Bitmap over all architectural registers used (src + dst), bit `i`
    /// = register index `i` (paper §4.2 register bitmap feature).
    pub reg_bitmap: u64,
    /// Effective data address for loads/stores (0 otherwise).
    pub mem_addr: u64,
    /// Access width in bytes for loads/stores (0 otherwise).
    pub mem_bytes: u8,
    /// For conditional branches: architectural outcome (taken?). Branch
    /// outcomes are program semantics, not microarchitecture, so they
    /// belong in the functional trace and feed the branch-history input
    /// feature (paper Figure 4).
    pub taken: bool,
}

impl FuncRecord {
    /// True for loads/stores.
    pub fn is_mem(&self) -> bool {
        self.mem_bytes != 0
    }

    /// Registers set in the bitmap.
    pub fn registers(&self) -> impl Iterator<Item = Reg> + '_ {
        (0..crate::isa::NUM_REGS).filter_map(|i| {
            if self.reg_bitmap & (1u64 << i) != 0 {
                Some(Reg::from_index(i))
            } else {
                None
            }
        })
    }
}

/// A functional trace: the committed stream of a program execution.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FunctionalTrace {
    /// Benchmark name.
    pub name: String,
    /// Committed records in program order.
    pub records: Vec<FuncRecord>,
}

/// Performance metrics of one *retired* instruction in a detailed trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetiredInfo {
    /// Static identity (same fields as the functional trace; alignment in
    /// `crate::dataset` matches on these).
    pub func: FuncRecord,
    /// Cycle the instruction was fetched.
    pub fetch_clock: u64,
    /// Cycle the instruction retired (committed).
    pub retire_clock: u64,
    /// Was this a mispredicted conditional branch?
    pub branch_mispred: bool,
    /// Data-cache service level for memory ops.
    pub access_level: AccessLevel,
    /// Did the fetch miss the L1 instruction cache?
    pub icache_miss: bool,
    /// Did the data access miss the TLB?
    pub tlb_miss: bool,
}

/// One record of a detailed trace, in fetch order.
///
/// §4.1: "the detailed trace contains incorrect speculative and stall
/// instructions" — both extra kinds are first-class records here so the
/// dataset-construction workflow can remove them and re-attribute their
/// timing, exactly as the paper's Figure 2 walks through.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DetailedRecord {
    /// An instruction that retired, with full metrics.
    Retired(RetiredInfo),
    /// A wrong-path (squashed speculative) instruction: fetched after a
    /// mispredicted branch, never committed.
    Squashed {
        /// PC of the wrong-path instruction.
        pc: u64,
        /// Its opcode.
        opcode: Opcode,
        /// Cycle it was fetched.
        fetch_clock: u64,
    },
    /// A pipeline-stall bubble: no instruction could be fetched/issued
    /// this cycle, modelled as a `nop` in the pipe (paper §4.1).
    NopStall {
        /// Cycle of the bubble.
        fetch_clock: u64,
    },
}

impl DetailedRecord {
    /// Fetch clock of the record, whatever its kind.
    pub fn fetch_clock(&self) -> u64 {
        match self {
            DetailedRecord::Retired(r) => r.fetch_clock,
            DetailedRecord::Squashed { fetch_clock, .. } => *fetch_clock,
            DetailedRecord::NopStall { fetch_clock } => *fetch_clock,
        }
    }

    /// The retired payload, if this record retired.
    pub fn retired(&self) -> Option<&RetiredInfo> {
        match self {
            DetailedRecord::Retired(r) => Some(r),
            _ => None,
        }
    }
}

/// A detailed trace plus run-level statistics the simulator reports
/// directly (the "gem5 ground truth" side of every evaluation figure).
#[derive(Debug, Clone, Default)]
pub struct DetailedTrace {
    /// Benchmark name.
    pub name: String,
    /// Microarchitecture name the trace was generated on.
    pub uarch: String,
    /// Records in fetch order.
    pub records: Vec<DetailedRecord>,
    /// Total simulated cycles (retire clock of the last instruction).
    pub total_cycles: u64,
}

impl DetailedTrace {
    /// Number of retired instructions.
    pub fn retired_count(&self) -> usize {
        self.records.iter().filter(|r| r.retired().is_some()).count()
    }

    /// Number of squashed speculative records.
    pub fn squashed_count(&self) -> usize {
        self.records
            .iter()
            .filter(|r| matches!(r, DetailedRecord::Squashed { .. }))
            .count()
    }

    /// Number of nop-stall records.
    pub fn nop_count(&self) -> usize {
        self.records
            .iter()
            .filter(|r| matches!(r, DetailedRecord::NopStall { .. }))
            .count()
    }

    /// Ground-truth CPI.
    pub fn cpi(&self) -> f64 {
        let n = self.retired_count();
        if n == 0 {
            return 0.0;
        }
        self.total_cycles as f64 / n as f64
    }

    /// Iterator over retired records only, in order.
    pub fn retired(&self) -> impl Iterator<Item = &RetiredInfo> {
        self.records.iter().filter_map(|r| r.retired())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_retired(fetch: u64, retire: u64) -> RetiredInfo {
        RetiredInfo {
            func: FuncRecord {
                pc: 0x400000,
                opcode: Opcode::Add,
                reg_bitmap: 0b110,
                mem_addr: 0,
                mem_bytes: 0,
                taken: false,
            },
            fetch_clock: fetch,
            retire_clock: retire,
            branch_mispred: false,
            access_level: AccessLevel::None,
            icache_miss: false,
            tlb_miss: false,
        }
    }

    #[test]
    fn access_level_round_trip() {
        for i in 0..AccessLevel::COUNT {
            assert_eq!(AccessLevel::from_index(i).index(), i);
        }
    }

    #[test]
    fn l1_miss_classification() {
        assert!(!AccessLevel::None.is_l1_miss());
        assert!(!AccessLevel::L1.is_l1_miss());
        assert!(AccessLevel::L2.is_l1_miss());
        assert!(AccessLevel::Mem.is_l1_miss());
    }

    #[test]
    fn func_record_register_iteration() {
        let r = FuncRecord {
            pc: 0,
            opcode: Opcode::Add,
            reg_bitmap: (1 << 0) | (1 << 33),
            mem_addr: 0,
            mem_bytes: 0,
            taken: false,
        };
        let regs: Vec<Reg> = r.registers().collect();
        assert_eq!(regs.len(), 2);
        assert_eq!(regs[0].index(), 0);
        assert_eq!(regs[1].index(), 33);
    }

    #[test]
    fn detailed_trace_counts() {
        let t = DetailedTrace {
            name: "t".into(),
            uarch: "A".into(),
            records: vec![
                DetailedRecord::Retired(sample_retired(0, 3)),
                DetailedRecord::Squashed {
                    pc: 4,
                    opcode: Opcode::Sub,
                    fetch_clock: 1,
                },
                DetailedRecord::NopStall { fetch_clock: 2 },
                DetailedRecord::Retired(sample_retired(3, 6)),
            ],
            total_cycles: 6,
        };
        assert_eq!(t.retired_count(), 2);
        assert_eq!(t.squashed_count(), 1);
        assert_eq!(t.nop_count(), 1);
        assert!((t.cpi() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn cpi_of_empty_trace_is_zero() {
        let t = DetailedTrace::default();
        assert_eq!(t.cpi(), 0.0);
    }
}
