//! TAOTFNC2: the column-specialized compressed on-disk trace format.
//!
//! TAOTFNC1 spends a flat 27 B/instruction. The columns it stores are
//! individually highly compressible — PCs advance by small deltas,
//! opcodes draw from a handful of values per region, memory addresses
//! are zero for non-memory ops and strided otherwise, branch outcomes
//! are a bit — so v2 encodes each column of each chunk with whichever
//! specialized encoding is smallest, and frames every chunk with a
//! CRC32 footer so corruption fails typed (the same discipline as the
//! serve cache journal) instead of garbling downstream consumers.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! header:  magic "TAOTFNC2"
//!          name        u64 length + bytes
//!          records     u64   (0 until back-patched by the writer's finish)
//!          chunk_rows  u64   (nominal rows per chunk)
//! chunk:   rows        u32   (1 ..= chunk_rows)
//!          payload_len u32
//!          payload     payload_len bytes
//!          crc32       u32   over the 8 framing bytes + payload
//! payload: six sections in column order
//!          (pc, opcode, reg_bitmap, mem_addr, mem_bytes, taken), each:
//!          encoding    u8
//!          byte_len    u32
//!          data        byte_len bytes
//! ```
//!
//! Chunks repeat until exactly `records` rows have been stored. After
//! the last chunk the writer (by default) appends a chunk-offset index
//! footer so seeks need not scan frame headers:
//!
//! ```text
//! footer:  magic "TAOTFIX1"
//!          chunk_count  u64   (must equal ceil(records / chunk_rows))
//!          offsets      chunk_count × u64 file offsets, ascending
//!          crc32        u32   over magic + count + offsets
//! ```
//!
//! Chunk `i` always starts at row `i * chunk_rows` (only the final
//! chunk may be short), so the footer needs no row column. The file
//! must end after the footer — or after the last chunk for index-less
//! files — and trailing bytes are an error, as in v1. Every decode-side
//! length, index, run and varint is validated, so a file that passes
//! its CRCs but lies about its contents still fails typed, never panics
//! or over-allocates.
//!
//! The reader ([`CompressedChunkSource`]) decodes inside `next_chunk`,
//! so wrapping it in the existing `ChunkPrefetcher` (as every pipelined
//! engine path already does) overlaps decompression with feature
//! staging and model execution — no new serial decode stage.

use super::chunk::{ChunkBuf, ChunkSource};
use super::columns::TraceColumns;
use super::format::{header_error, read_magic, TraceError, TraceFormat};
use super::serialize::{read_str, read_u64, write_str, write_u64};
use crate::isa::Opcode;
use crate::util::hash::crc32;
use anyhow::{bail, ensure, Context, Result};
use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

pub(crate) const MAGIC_V2: &[u8; 8] = b"TAOTFNC2";

/// Magic opening the optional chunk-offset index footer.
pub(crate) const MAGIC_INDEX: &[u8; 8] = b"TAOTFIX1";

/// Hard cap on a chunk's row count; bounds decode-side staging memory
/// against a corrupt or hostile header.
pub(crate) const MAX_CHUNK_ROWS: usize = 1 << 22;

/// Hard cap on a chunk's encoded payload; bounds the frame buffer a
/// reader allocates before the CRC has vouched for the chunk.
const MAX_PAYLOAD: usize = 1 << 28;

/// Highest compression level (see [`TraceWriteOptions::level`]
/// (super::format::TraceWriteOptions)).
pub(crate) const MAX_LEVEL: u8 = 2;

// Column-section encoding tags. 0..=3 are u64-column encodings,
// 4..=7 are u8-column encodings; a tag in the wrong column family is
// rejected on decode.
const ENC_RAW64: u8 = 0;
const ENC_DELTA_VARINT: u8 = 1;
const ENC_DICT64: u8 = 2;
const ENC_SPARSE_DELTA: u8 = 3;
const ENC_RAW8: u8 = 4;
const ENC_RLE8: u8 = 5;
const ENC_BITPACK: u8 = 6;
const ENC_NIBBLE_DICT: u8 = 7;

/// The escape index in a nibble-dictionary section: the value is not
/// in the dictionary and is spilled to the escape stream instead.
const NIBBLE_ESCAPE: u8 = 0xF;

/// Column-section names, in on-disk order (diagnostics / `tao trace
/// inspect`).
pub(crate) const SECTION_NAMES: [&str; 6] =
    ["pc", "opcode", "reg_bitmap", "mem_addr", "mem_bytes", "taken"];

// ---------------------------------------------------------------------
// Primitive encodings
// ---------------------------------------------------------------------

fn zigzag(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

fn unzigzag(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(data: &[u8], pos: &mut usize) -> Result<u64> {
    let mut v = 0u64;
    for shift in (0..64).step_by(7) {
        ensure!(*pos < data.len(), "varint runs past the section");
        let b = data[*pos];
        *pos += 1;
        let bits = (b & 0x7f) as u64;
        ensure!(shift < 63 || bits <= 1, "varint overflows 64 bits");
        v |= bits << shift;
        if b & 0x80 == 0 {
            return Ok(v);
        }
    }
    bail!("varint longer than 10 bytes");
}

// -- u64 columns -------------------------------------------------------

fn raw64_encode(vals: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 8);
    for &v in vals {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

fn raw64_decode(data: &[u8], rows: usize, out: &mut Vec<u64>) -> Result<()> {
    ensure!(
        data.len() == rows * 8,
        "raw64 section: {} bytes for {rows} rows",
        data.len()
    );
    for c in data.chunks_exact(8) {
        out.push(u64::from_le_bytes(c.try_into().unwrap()));
    }
    Ok(())
}

/// Zig-zag varint of the wrapping delta to the previous value
/// (implicit 0 before the first row). PCs and strided addresses
/// collapse to 1–2 bytes per row.
fn delta_varint_encode(vals: &[u64]) -> Vec<u8> {
    let mut out = Vec::with_capacity(vals.len() * 2);
    let mut prev = 0u64;
    for &v in vals {
        push_varint(&mut out, zigzag(v.wrapping_sub(prev) as i64));
        prev = v;
    }
    out
}

fn delta_varint_decode(data: &[u8], rows: usize, out: &mut Vec<u64>) -> Result<()> {
    let mut pos = 0usize;
    let mut prev = 0u64;
    for _ in 0..rows {
        let d = read_varint(data, &mut pos)?;
        prev = prev.wrapping_add(unzigzag(d) as u64);
        out.push(prev);
    }
    ensure!(
        pos == data.len(),
        "delta section: {} trailing bytes",
        data.len() - pos
    );
    Ok(())
}

/// Presence bitmap + delta varints over the nonzero values only.
/// Memory addresses are 0 for every non-memory instruction, so mixed
/// streams pay one bit per row plus bytes only where a load/store is.
fn sparse_delta_encode(vals: &[u64]) -> Vec<u8> {
    let mut out = vec![0u8; vals.len().div_ceil(8)];
    for (i, &v) in vals.iter().enumerate() {
        if v != 0 {
            out[i / 8] |= 1 << (i % 8);
        }
    }
    let mut prev = 0u64;
    for &v in vals {
        if v != 0 {
            push_varint(&mut out, zigzag(v.wrapping_sub(prev) as i64));
            prev = v;
        }
    }
    out
}

fn sparse_delta_decode(data: &[u8], rows: usize, out: &mut Vec<u64>) -> Result<()> {
    let bitmap_len = rows.div_ceil(8);
    ensure!(
        data.len() >= bitmap_len,
        "sparse section shorter than its presence bitmap"
    );
    let (bitmap, rest) = data.split_at(bitmap_len);
    if rows % 8 != 0 {
        ensure!(
            bitmap[bitmap_len - 1] >> (rows % 8) == 0,
            "sparse bitmap has bits past the last row"
        );
    }
    let mut pos = 0usize;
    let mut prev = 0u64;
    for i in 0..rows {
        if (bitmap[i / 8] >> (i % 8)) & 1 == 1 {
            let d = read_varint(rest, &mut pos)?;
            prev = prev.wrapping_add(unzigzag(d) as u64);
            out.push(prev);
        } else {
            out.push(0);
        }
    }
    ensure!(
        pos == rest.len(),
        "sparse section: {} trailing bytes",
        rest.len() - pos
    );
    Ok(())
}

/// `[count u16][count × u64 values][rows × u8 index]` — one byte per
/// row when a chunk draws from at most 256 distinct values (register
/// bitmaps, in practice). Returns `None` past 256 distinct.
fn dict64_encode(vals: &[u64]) -> Option<Vec<u8>> {
    let mut dict: Vec<u64> = Vec::new();
    let mut index: HashMap<u64, u8> = HashMap::new();
    let mut idxs: Vec<u8> = Vec::with_capacity(vals.len());
    for &v in vals {
        let id = match index.get(&v) {
            Some(&id) => id,
            None => {
                if dict.len() == 256 {
                    return None;
                }
                let id = dict.len() as u8;
                dict.push(v);
                index.insert(v, id);
                id
            }
        };
        idxs.push(id);
    }
    let mut out = Vec::with_capacity(2 + dict.len() * 8 + idxs.len());
    out.extend_from_slice(&(dict.len() as u16).to_le_bytes());
    for &v in &dict {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&idxs);
    Some(out)
}

fn dict64_decode(data: &[u8], rows: usize, out: &mut Vec<u64>) -> Result<()> {
    ensure!(data.len() >= 2, "dict64 section too short for its count");
    let count = u16::from_le_bytes([data[0], data[1]]) as usize;
    ensure!(count <= 256, "dict64 with {count} entries");
    let need = 2 + count * 8 + rows;
    ensure!(
        data.len() == need,
        "dict64 section: {} bytes, expected {need}",
        data.len()
    );
    let values: Vec<u64> = data[2..2 + count * 8]
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect();
    for &id in &data[2 + count * 8..] {
        match values.get(id as usize) {
            Some(&v) => out.push(v),
            None => bail!("dict64 index {id} out of range ({count} entries)"),
        }
    }
    Ok(())
}

fn encode_u64_column(vals: &[u64], level: u8) -> (u8, Vec<u8>) {
    let mut cands: Vec<(u8, Vec<u8>)> = vec![(ENC_RAW64, raw64_encode(vals))];
    if level >= 1 {
        cands.push((ENC_DELTA_VARINT, delta_varint_encode(vals)));
        cands.push((ENC_SPARSE_DELTA, sparse_delta_encode(vals)));
    }
    if level >= 2 {
        if let Some(d) = dict64_encode(vals) {
            cands.push((ENC_DICT64, d));
        }
    }
    cands.into_iter().min_by_key(|(_, d)| d.len()).unwrap()
}

fn decode_u64_section(enc: u8, data: &[u8], rows: usize, out: &mut Vec<u64>) -> Result<()> {
    match enc {
        ENC_RAW64 => raw64_decode(data, rows, out),
        ENC_DELTA_VARINT => delta_varint_decode(data, rows, out),
        ENC_SPARSE_DELTA => sparse_delta_decode(data, rows, out),
        ENC_DICT64 => dict64_decode(data, rows, out),
        other => bail!("unknown u64-column encoding tag {other}"),
    }
}

// -- u8 columns --------------------------------------------------------

/// `[value u8][run varint]` pairs; runs must sum to the row count.
fn rle8_encode(vals: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut i = 0usize;
    while i < vals.len() {
        let v = vals[i];
        let mut j = i + 1;
        while j < vals.len() && vals[j] == v {
            j += 1;
        }
        out.push(v);
        push_varint(&mut out, (j - i) as u64);
        i = j;
    }
    out
}

fn rle8_decode(data: &[u8], rows: usize, out: &mut Vec<u8>) -> Result<()> {
    let mut pos = 0usize;
    let mut total = 0usize;
    while total < rows {
        ensure!(
            pos < data.len(),
            "rle section ends at row {total} of {rows}"
        );
        let v = data[pos];
        pos += 1;
        let run = read_varint(data, &mut pos)?;
        ensure!(
            run >= 1 && run <= (rows - total) as u64,
            "rle run of {run} at row {total} of {rows}"
        );
        let new_len = out.len() + run as usize;
        out.resize(new_len, v);
        total += run as usize;
    }
    ensure!(
        pos == data.len(),
        "rle section: {} trailing bytes",
        data.len() - pos
    );
    Ok(())
}

/// One bit per row, LSB-first; only valid when every value is 0 or 1
/// (branch outcomes).
fn bitpack_encode(vals: &[u8]) -> Vec<u8> {
    let mut out = vec![0u8; vals.len().div_ceil(8)];
    for (i, &v) in vals.iter().enumerate() {
        out[i / 8] |= (v & 1) << (i % 8);
    }
    out
}

fn bitpack_decode(data: &[u8], rows: usize, out: &mut Vec<u8>) -> Result<()> {
    ensure!(
        data.len() == rows.div_ceil(8),
        "bitpack section: {} bytes for {rows} rows",
        data.len()
    );
    if rows % 8 != 0 {
        ensure!(
            data[data.len() - 1] >> (rows % 8) == 0,
            "bitpack padding bits not zero"
        );
    }
    for i in 0..rows {
        out.push((data[i / 8] >> (i % 8)) & 1);
    }
    Ok(())
}

/// `[count u8 ≤ 15][count dict bytes][⌈rows/2⌉ packed nibbles][escape
/// bytes]` — half a byte per row for chunks drawing from at most 15
/// distinct values (opcodes, access widths). Nibble 0xF escapes to the
/// spill stream, so higher cardinality degrades instead of failing.
fn nibble_dict_encode(vals: &[u8]) -> Vec<u8> {
    let mut dict: Vec<u8> = Vec::new();
    let mut nibbles: Vec<u8> = Vec::with_capacity(vals.len());
    let mut escapes: Vec<u8> = Vec::new();
    for &v in vals {
        match dict.iter().position(|&d| d == v) {
            Some(i) => nibbles.push(i as u8),
            None if dict.len() < 15 => {
                nibbles.push(dict.len() as u8);
                dict.push(v);
            }
            None => {
                nibbles.push(NIBBLE_ESCAPE);
                escapes.push(v);
            }
        }
    }
    let mut packed = vec![0u8; vals.len().div_ceil(2)];
    for (i, &n) in nibbles.iter().enumerate() {
        packed[i / 2] |= n << (4 * (i % 2));
    }
    let mut out = Vec::with_capacity(1 + dict.len() + packed.len() + escapes.len());
    out.push(dict.len() as u8);
    out.extend_from_slice(&dict);
    out.extend_from_slice(&packed);
    out.extend_from_slice(&escapes);
    out
}

fn nibble_dict_decode(data: &[u8], rows: usize, out: &mut Vec<u8>) -> Result<()> {
    ensure!(!data.is_empty(), "nibble-dict section empty");
    let count = data[0] as usize;
    ensure!(count <= 15, "nibble dict with {count} entries");
    let packed_len = rows.div_ceil(2);
    ensure!(
        data.len() >= 1 + count + packed_len,
        "nibble-dict section too short"
    );
    let dict = &data[1..1 + count];
    let packed = &data[1 + count..1 + count + packed_len];
    let mut escapes = &data[1 + count + packed_len..];
    if rows % 2 == 1 {
        ensure!(
            packed[packed_len - 1] >> 4 == 0,
            "nibble padding not zero"
        );
    }
    for i in 0..rows {
        let n = (packed[i / 2] >> (4 * (i % 2))) & 0xF;
        if (n as usize) < count {
            out.push(dict[n as usize]);
        } else if n == NIBBLE_ESCAPE {
            match escapes.split_first() {
                Some((&v, rest)) => {
                    out.push(v);
                    escapes = rest;
                }
                None => bail!("nibble escape stream exhausted at row {i}"),
            }
        } else {
            bail!("nibble index {n} out of range ({count} entries)");
        }
    }
    ensure!(
        escapes.is_empty(),
        "{} trailing escape bytes",
        escapes.len()
    );
    Ok(())
}

fn encode_u8_column(vals: &[u8], level: u8) -> (u8, Vec<u8>) {
    let mut cands: Vec<(u8, Vec<u8>)> = vec![(ENC_RAW8, vals.to_vec())];
    if level >= 1 {
        cands.push((ENC_RLE8, rle8_encode(vals)));
        if vals.iter().all(|&v| v <= 1) {
            cands.push((ENC_BITPACK, bitpack_encode(vals)));
        }
    }
    if level >= 2 {
        cands.push((ENC_NIBBLE_DICT, nibble_dict_encode(vals)));
    }
    cands.into_iter().min_by_key(|(_, d)| d.len()).unwrap()
}

fn decode_u8_section(enc: u8, data: &[u8], rows: usize, out: &mut Vec<u8>) -> Result<()> {
    match enc {
        ENC_RAW8 => {
            ensure!(
                data.len() == rows,
                "raw8 section: {} bytes for {rows} rows",
                data.len()
            );
            out.extend_from_slice(data);
            Ok(())
        }
        ENC_RLE8 => rle8_decode(data, rows, out),
        ENC_BITPACK => bitpack_decode(data, rows, out),
        ENC_NIBBLE_DICT => nibble_dict_decode(data, rows, out),
        other => bail!("unknown u8-column encoding tag {other}"),
    }
}

// ---------------------------------------------------------------------
// Chunk payloads
// ---------------------------------------------------------------------

fn push_section(payload: &mut Vec<u8>, enc: u8, data: &[u8]) {
    payload.push(enc);
    payload.extend_from_slice(&(data.len() as u32).to_le_bytes());
    payload.extend_from_slice(data);
}

/// Encode `cols[lo..hi)` into one chunk payload at `level`. Each column
/// independently gets the smallest encoding its level allows (raw is
/// always a candidate, so compression never inflates a column by more
/// than the 5-byte section header).
pub(crate) fn encode_chunk_payload(
    cols: &TraceColumns,
    lo: usize,
    hi: usize,
    level: u8,
) -> Vec<u8> {
    let mut payload = Vec::new();
    let (enc, data) = encode_u64_column(&cols.pc[lo..hi], level);
    push_section(&mut payload, enc, &data);
    let (enc, data) = encode_u8_column(&cols.opcode[lo..hi], level);
    push_section(&mut payload, enc, &data);
    let (enc, data) = encode_u64_column(&cols.reg_bitmap[lo..hi], level);
    push_section(&mut payload, enc, &data);
    let (enc, data) = encode_u64_column(&cols.mem_addr[lo..hi], level);
    push_section(&mut payload, enc, &data);
    let (enc, data) = encode_u8_column(&cols.mem_bytes[lo..hi], level);
    push_section(&mut payload, enc, &data);
    let (enc, data) = encode_u8_column(&cols.taken[lo..hi], level);
    push_section(&mut payload, enc, &data);
    payload
}

fn take_section<'a>(payload: &'a [u8], pos: &mut usize, what: &str) -> Result<(u8, &'a [u8])> {
    ensure!(
        *pos + 5 <= payload.len(),
        "{what} section header runs past the payload"
    );
    let enc = payload[*pos];
    let len = u32::from_le_bytes(payload[*pos + 1..*pos + 5].try_into().unwrap()) as usize;
    *pos += 5;
    ensure!(
        *pos + len <= payload.len(),
        "{what} section data runs past the payload"
    );
    let data = &payload[*pos..*pos + len];
    *pos += len;
    Ok((enc, data))
}

/// Decode one chunk payload, appending `rows` records to `into`.
/// Returns the encoded byte length of each column section (for
/// `tao trace inspect`). Opcode ids are validated exactly as the v1
/// reader validates them.
pub(crate) fn decode_chunk_payload(
    payload: &[u8],
    rows: usize,
    into: &mut TraceColumns,
) -> Result<[usize; 6]> {
    let mut pos = 0usize;
    let mut sizes = [0usize; 6];

    let (enc, data) = take_section(payload, &mut pos, SECTION_NAMES[0])?;
    sizes[0] = data.len();
    decode_u64_section(enc, data, rows, &mut into.pc).context("pc column")?;

    let (enc, data) = take_section(payload, &mut pos, SECTION_NAMES[1])?;
    sizes[1] = data.len();
    let op_start = into.opcode.len();
    decode_u8_section(enc, data, rows, &mut into.opcode).context("opcode column")?;
    for &op in &into.opcode[op_start..] {
        ensure!((op as usize) < Opcode::COUNT, "bad opcode id {op}");
    }

    let (enc, data) = take_section(payload, &mut pos, SECTION_NAMES[2])?;
    sizes[2] = data.len();
    decode_u64_section(enc, data, rows, &mut into.reg_bitmap).context("reg_bitmap column")?;

    let (enc, data) = take_section(payload, &mut pos, SECTION_NAMES[3])?;
    sizes[3] = data.len();
    decode_u64_section(enc, data, rows, &mut into.mem_addr).context("mem_addr column")?;

    let (enc, data) = take_section(payload, &mut pos, SECTION_NAMES[4])?;
    sizes[4] = data.len();
    decode_u8_section(enc, data, rows, &mut into.mem_bytes).context("mem_bytes column")?;

    let (enc, data) = take_section(payload, &mut pos, SECTION_NAMES[5])?;
    sizes[5] = data.len();
    decode_u8_section(enc, data, rows, &mut into.taken).context("taken column")?;

    ensure!(
        pos == payload.len(),
        "{} trailing payload bytes",
        payload.len() - pos
    );
    Ok(sizes)
}

// ---------------------------------------------------------------------
// Streaming writer
// ---------------------------------------------------------------------

/// Streaming `TAOTFNC2` writer. Appended rows accumulate until a full
/// `chunk_rows` chunk can be encoded and flushed, so the file's chunk
/// boundaries — and therefore its bytes — are independent of the append
/// granularity; only the final chunk may be short. The record count in
/// the header is back-patched on [`V2Writer::finish`], so producers
/// that discover their length while streaming (simulators, transcodes)
/// need no up-front count.
pub(crate) struct V2Writer {
    path: PathBuf,
    w: BufWriter<std::fs::File>,
    count_offset: u64,
    chunk_rows: usize,
    level: u8,
    index: bool,
    /// Byte offset the next chunk will land at.
    offset: u64,
    /// File offset of every flushed chunk, for the index footer.
    chunk_offsets: Vec<u64>,
    pending: TraceColumns,
    written: u64,
}

impl V2Writer {
    pub(crate) fn create(
        path: &Path,
        name: &str,
        chunk_rows: usize,
        level: u8,
        index: bool,
    ) -> Result<V2Writer> {
        ensure!(
            chunk_rows >= 1 && chunk_rows <= MAX_CHUNK_ROWS,
            "chunk_rows {chunk_rows} out of range 1..={MAX_CHUNK_ROWS}"
        );
        ensure!(
            level <= MAX_LEVEL,
            "compression level {level} out of range 0..={MAX_LEVEL}"
        );
        let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(file);
        w.write_all(MAGIC_V2)?;
        write_str(&mut w, name)?;
        let count_offset = 8 + 8 + name.len() as u64;
        write_u64(&mut w, 0)?; // record count, back-patched by finish()
        write_u64(&mut w, chunk_rows as u64)?;
        Ok(V2Writer {
            path: path.to_path_buf(),
            w,
            count_offset,
            chunk_rows,
            level,
            index,
            offset: count_offset + 16, // past the count and chunk_rows words
            chunk_offsets: Vec::new(),
            pending: TraceColumns::new(),
            written: 0,
        })
    }

    pub(crate) fn append(&mut self, cols: &TraceColumns) -> Result<()> {
        self.pending.extend_from(cols, 0, cols.len());
        while self.pending.len() >= self.chunk_rows {
            self.flush_rows(self.chunk_rows)?;
        }
        Ok(())
    }

    /// Rows appended so far (flushed + pending).
    pub(crate) fn rows_appended(&self) -> u64 {
        self.written + self.pending.len() as u64
    }

    fn flush_rows(&mut self, rows: usize) -> Result<()> {
        let payload = encode_chunk_payload(&self.pending, 0, rows, self.level);
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(rows as u32).to_le_bytes());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&payload);
        let crc = crc32(&frame);
        self.w
            .write_all(&frame)
            .and_then(|()| self.w.write_all(&crc.to_le_bytes()))
            .with_context(|| format!("write {:?}", self.path))?;
        self.chunk_offsets.push(self.offset);
        self.offset += frame.len() as u64 + 4;
        self.written += rows as u64;
        let mut rest = TraceColumns::with_capacity(self.pending.len() - rows);
        rest.extend_from(&self.pending, rows, self.pending.len());
        self.pending = rest;
        Ok(())
    }

    pub(crate) fn finish(mut self) -> Result<u64> {
        if !self.pending.is_empty() {
            let rows = self.pending.len();
            self.flush_rows(rows)?;
        }
        if self.index {
            debug_assert_eq!(
                self.chunk_offsets.len() as u64,
                self.written.div_ceil(self.chunk_rows as u64)
            );
            let mut footer = Vec::with_capacity(16 + self.chunk_offsets.len() * 8);
            footer.extend_from_slice(MAGIC_INDEX);
            footer.extend_from_slice(&(self.chunk_offsets.len() as u64).to_le_bytes());
            for &off in &self.chunk_offsets {
                footer.extend_from_slice(&off.to_le_bytes());
            }
            let crc = crc32(&footer);
            self.w
                .write_all(&footer)
                .and_then(|()| self.w.write_all(&crc.to_le_bytes()))
                .with_context(|| format!("write index footer in {:?}", self.path))?;
        }
        self.w.flush().with_context(|| format!("flush {:?}", self.path))?;
        let f = self.w.get_mut();
        f.seek(SeekFrom::Start(self.count_offset))
            .and_then(|_| f.write_all(&self.written.to_le_bytes()))
            .with_context(|| format!("back-patch record count in {:?}", self.path))?;
        Ok(self.written)
    }
}

// ---------------------------------------------------------------------
// Streaming reader
// ---------------------------------------------------------------------

/// Per-chunk metadata from the decode path (consumed by `scan_v2`).
struct ChunkMeta {
    payload_len: usize,
    sections: [usize; 6],
}

/// Streams a `TAOTFNC2` file in bounded chunks — the compressed sibling
/// of [`FileChunkSource`](super::chunk::FileChunkSource), behind the
/// same [`ChunkSource`] contract. One disk chunk at a time is decoded
/// into a staging buffer and served out in `max_rows` slices, so a
/// consumer's chunk size need not match the writer's. CRC mismatches,
/// truncated tails, trailing bytes and every malformed section surface
/// as typed [`TraceError`]s.
pub struct CompressedChunkSource {
    path: PathBuf,
    name: String,
    reader: BufReader<std::fs::File>,
    declared: u64,
    chunk_rows: u64,
    /// Rows decoded off disk (&le; declared).
    decoded: u64,
    /// Rows handed to the consumer (&le; decoded).
    delivered: u64,
    /// Ordinal of the next disk chunk, for error reporting.
    chunk_index: usize,
    staged: TraceColumns,
    staged_pos: usize,
    /// Byte offset of the first chunk frame.
    data_start: u64,
    /// Chunk file offsets, loaded lazily on first seek (from the index
    /// footer, or a frame-header scan for index-less files) and cached.
    index: Option<Vec<u64>>,
    /// Whether a valid `TAOTFIX1` footer has been observed.
    saw_index: bool,
}

impl CompressedChunkSource {
    /// Open `path` and validate the `TAOTFNC2` header.
    pub fn open(path: &Path) -> Result<CompressedChunkSource> {
        let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        let mut reader = BufReader::new(file);
        let found = read_magic(path, &mut reader)?;
        if found != TraceFormat::V2 {
            return Err(TraceError::WrongFormat {
                path: path.to_path_buf(),
                found,
                expected: TraceFormat::V2,
            }
            .into());
        }
        let header = (|| -> Result<(String, u64, u64)> {
            let name = read_str(&mut reader)?;
            let declared = read_u64(&mut reader)?;
            let chunk_rows = read_u64(&mut reader)?;
            Ok((name, declared, chunk_rows))
        })();
        let (name, declared, chunk_rows) = header.map_err(|e| header_error(path, e))?;
        ensure!(
            usize::try_from(declared).is_ok(),
            "{path:?}: unrepresentable record count {declared}"
        );
        ensure!(
            chunk_rows >= 1 && chunk_rows <= MAX_CHUNK_ROWS as u64,
            "{path:?}: unreasonable chunk size {chunk_rows}"
        );
        let data_start = (8 + 8 + name.len() + 8 + 8) as u64;
        let mut src = CompressedChunkSource {
            path: path.to_path_buf(),
            name,
            reader,
            declared,
            chunk_rows,
            decoded: 0,
            delivered: 0,
            chunk_index: 0,
            staged: TraceColumns::new(),
            staged_pos: 0,
            data_start,
            index: None,
            saw_index: false,
        };
        if declared == 0 {
            src.check_eof()?;
        }
        Ok(src)
    }

    /// Trace name from the header.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Nominal rows per chunk from the header.
    pub fn chunk_rows(&self) -> u64 {
        self.chunk_rows
    }

    fn remaining_on_disk(&self) -> u64 {
        self.declared - self.decoded
    }

    fn staged_avail(&self) -> usize {
        self.staged.len() - self.staged_pos
    }

    /// After the declared record count is consumed, the file must hold
    /// either nothing or a valid index footer; anything else is typed
    /// trailing garbage (or a typed corrupt index when the footer magic
    /// matches but the body doesn't validate).
    fn check_eof(&mut self) -> Result<()> {
        let mut probe = [0u8; 8];
        let mut got = 0usize;
        while got < 8 {
            match self.reader.read(&mut probe[got..]) {
                Ok(0) => break,
                Ok(n) => got += n,
                Err(e) => {
                    return Err(e).with_context(|| format!("probe EOF in {:?}", self.path))
                }
            }
        }
        if got == 0 {
            return Ok(());
        }
        if got == 8 && probe == *MAGIC_INDEX {
            let offsets = self.read_footer_body(true)?;
            self.saw_index = true;
            if self.index.is_none() {
                self.index = Some(offsets);
            }
            return Ok(());
        }
        Err(TraceError::TrailingGarbage {
            path: self.path.clone(),
            declared: self.declared,
        }
        .into())
    }

    /// Expected footer chunk count: chunk `i` always starts at row
    /// `i * chunk_rows`, so the count is fully determined by the header.
    fn expected_chunks(&self) -> u64 {
        self.declared.div_ceil(self.chunk_rows)
    }

    fn corrupt_index(&self, detail: String) -> anyhow::Error {
        TraceError::CorruptIndex {
            path: self.path.clone(),
            detail,
        }
        .into()
    }

    /// Read and validate the footer body — the reader is positioned
    /// just past the footer magic. Returns the chunk offsets; with
    /// `probe_eof`, also insists the file ends right after the footer.
    fn read_footer_body(&mut self, probe_eof: bool) -> Result<Vec<u64>> {
        let expected = self.expected_chunks();
        let mut body = vec![0u8; 8 + expected as usize * 8];
        if let Err(e) = self.reader.read_exact(&mut body) {
            return if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Err(self.corrupt_index("truncated index footer".to_string()))
            } else {
                Err(e).with_context(|| format!("read index footer in {:?}", self.path))
            };
        }
        let count = u64::from_le_bytes(body[..8].try_into().unwrap());
        if count != expected {
            return Err(self.corrupt_index(format!(
                "{count} chunk offsets for {expected} chunks"
            )));
        }
        let mut crc_bytes = [0u8; 4];
        if let Err(e) = self.reader.read_exact(&mut crc_bytes) {
            return if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Err(self.corrupt_index("truncated index footer".to_string()))
            } else {
                Err(e).with_context(|| format!("read index footer in {:?}", self.path))
            };
        }
        let stored = u32::from_le_bytes(crc_bytes);
        let mut hashed = Vec::with_capacity(8 + body.len());
        hashed.extend_from_slice(MAGIC_INDEX);
        hashed.extend_from_slice(&body);
        let computed = crc32(&hashed);
        if stored != computed {
            return Err(self.corrupt_index(format!(
                "CRC mismatch (stored {stored:#010x}, computed {computed:#010x})"
            )));
        }
        let mut offsets = Vec::with_capacity(expected as usize);
        for c in body[8..].chunks_exact(8) {
            let off = u64::from_le_bytes(c.try_into().unwrap());
            let ok = off >= self.data_start && offsets.last().map_or(true, |&prev| off > prev);
            if !ok {
                return Err(self.corrupt_index(format!("non-ascending chunk offset {off}")));
            }
            offsets.push(off);
        }
        if probe_eof {
            let mut p = [0u8; 1];
            match self.reader.read(&mut p) {
                Ok(0) => {}
                Ok(_) => {
                    return Err(TraceError::TrailingGarbage {
                        path: self.path.clone(),
                        declared: self.declared,
                    }
                    .into())
                }
                Err(e) => {
                    return Err(e).with_context(|| format!("probe EOF in {:?}", self.path))
                }
            }
        }
        Ok(offsets)
    }

    /// Make sure the chunk-offset table is loaded: try the index footer
    /// first (EOF-anchored — its size is fully determined by the
    /// header), fall back to a frame-header scan that skips every
    /// payload without decoding it. Either result is cached.
    fn ensure_index(&mut self) -> Result<()> {
        if self.index.is_some() {
            return Ok(());
        }
        let expected = self.expected_chunks();
        let footer_len = expected.saturating_mul(8).saturating_add(20);
        let file_len = self
            .reader
            .get_ref()
            .metadata()
            .with_context(|| format!("stat {:?}", self.path))?
            .len();
        if file_len >= self.data_start + footer_len {
            let footer_off = file_len - footer_len;
            self.reader
                .seek(SeekFrom::Start(footer_off))
                .with_context(|| format!("seek in {:?}", self.path))?;
            let mut magic = [0u8; 8];
            let found = match self.reader.read_exact(&mut magic) {
                Ok(()) => magic == *MAGIC_INDEX,
                Err(_) => false,
            };
            if found {
                let offsets = self.read_footer_body(false)?;
                self.saw_index = true;
                self.index = Some(offsets);
                return Ok(());
            }
        }
        let offsets = self.scan_chunk_offsets()?;
        self.index = Some(offsets);
        Ok(())
    }

    /// Index-less fallback: walk the chunk frame headers from the top,
    /// seeking past each payload without decoding it, and record where
    /// every chunk starts.
    fn scan_chunk_offsets(&mut self) -> Result<Vec<u64>> {
        let expected = self.expected_chunks();
        let mut offsets = Vec::with_capacity(expected as usize);
        let mut pos = self.data_start;
        let mut rows_seen = 0u64;
        for i in 0..expected {
            self.reader
                .seek(SeekFrom::Start(pos))
                .with_context(|| format!("seek in {:?}", self.path))?;
            let mut head = [0u8; 8];
            if let Err(e) = self.reader.read_exact(&mut head) {
                return if e.kind() == std::io::ErrorKind::UnexpectedEof {
                    Err(TraceError::TruncatedTail {
                        path: self.path.clone(),
                        declared: self.declared,
                        got: rows_seen,
                    }
                    .into())
                } else {
                    Err(e).with_context(|| format!("read {:?}", self.path))
                };
            }
            let rows = u32::from_le_bytes(head[0..4].try_into().unwrap()) as u64;
            let payload_len = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
            if rows == 0 || rows > self.chunk_rows || rows > self.declared - rows_seen {
                return Err(TraceError::CorruptChunk {
                    path: self.path.clone(),
                    chunk: i as usize,
                    detail: format!("{rows} rows in the frame header"),
                }
                .into());
            }
            if payload_len > MAX_PAYLOAD {
                return Err(TraceError::CorruptChunk {
                    path: self.path.clone(),
                    chunk: i as usize,
                    detail: format!("unreasonable payload length {payload_len}"),
                }
                .into());
            }
            offsets.push(pos);
            rows_seen += rows;
            pos += 8 + payload_len as u64 + 4;
        }
        Ok(offsets)
    }

    /// Reposition so the next pulled row is `row`, decoding at most one
    /// chunk. `row == declared` positions at end-of-stream; beyond that
    /// is an error.
    pub fn seek_to_row(&mut self, row: u64) -> Result<()> {
        ensure!(
            row <= self.declared,
            "{:?}: seek to row {row} past the {} declared records",
            self.path,
            self.declared
        );
        self.staged.clear();
        self.staged_pos = 0;
        if row == self.declared {
            self.decoded = self.declared;
            self.delivered = row;
            self.chunk_index = self.expected_chunks() as usize;
            return Ok(());
        }
        let target = row / self.chunk_rows;
        self.ensure_index()?;
        let off = self.index.as_ref().unwrap()[target as usize];
        self.reader
            .seek(SeekFrom::Start(off))
            .with_context(|| format!("seek in {:?}", self.path))?;
        self.decoded = target * self.chunk_rows;
        self.chunk_index = target as usize;
        self.decode_next_chunk()?;
        let skip = (row - target * self.chunk_rows) as usize;
        if skip >= self.staged.len() {
            return Err(TraceError::CorruptChunk {
                path: self.path.clone(),
                chunk: target as usize,
                detail: format!(
                    "chunk holds {} rows, cannot reach row {row}",
                    self.staged.len()
                ),
            }
            .into());
        }
        self.staged_pos = skip;
        self.delivered = row;
        Ok(())
    }

    fn tail_err(&self, e: std::io::Error) -> anyhow::Error {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            TraceError::TruncatedTail {
                path: self.path.clone(),
                declared: self.declared,
                got: self.decoded,
            }
            .into()
        } else {
            anyhow::Error::new(e).context(format!("read {:?}", self.path))
        }
    }

    fn corrupt(&self, detail: String) -> anyhow::Error {
        TraceError::CorruptChunk {
            path: self.path.clone(),
            chunk: self.chunk_index,
            detail,
        }
        .into()
    }

    /// Read, CRC-check and decode the next disk chunk into the staging
    /// buffer.
    fn decode_next_chunk(&mut self) -> Result<ChunkMeta> {
        let mut head = [0u8; 8];
        self.reader
            .read_exact(&mut head)
            .map_err(|e| self.tail_err(e))?;
        let rows = u32::from_le_bytes(head[0..4].try_into().unwrap()) as u64;
        let payload_len = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        if rows == 0 || rows > self.chunk_rows {
            return Err(self.corrupt(format!(
                "{rows} rows in a {}-rows-per-chunk trace",
                self.chunk_rows
            )));
        }
        if rows > self.remaining_on_disk() {
            return Err(self.corrupt(format!(
                "chunk of {rows} rows exceeds the {} undecoded records",
                self.remaining_on_disk()
            )));
        }
        if payload_len > MAX_PAYLOAD {
            return Err(self.corrupt(format!("unreasonable payload length {payload_len}")));
        }
        let mut frame = vec![0u8; 8 + payload_len];
        frame[..8].copy_from_slice(&head);
        self.reader
            .read_exact(&mut frame[8..])
            .map_err(|e| self.tail_err(e))?;
        let mut crc_bytes = [0u8; 4];
        self.reader
            .read_exact(&mut crc_bytes)
            .map_err(|e| self.tail_err(e))?;
        let stored = u32::from_le_bytes(crc_bytes);
        let computed = crc32(&frame);
        if stored != computed {
            return Err(TraceError::CrcMismatch {
                path: self.path.clone(),
                chunk: self.chunk_index,
                stored,
                computed,
            }
            .into());
        }
        self.staged.clear();
        self.staged_pos = 0;
        let sections = decode_chunk_payload(&frame[8..], rows as usize, &mut self.staged)
            .map_err(|e| self.corrupt(format!("{e:#}")))?;
        self.decoded += rows;
        self.chunk_index += 1;
        if self.remaining_on_disk() == 0 {
            self.check_eof()?;
        }
        Ok(ChunkMeta {
            payload_len,
            sections,
        })
    }
}

impl ChunkSource for CompressedChunkSource {
    fn len_hint(&self) -> Option<usize> {
        usize::try_from(self.declared - self.delivered).ok()
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        buf.clear();
        let mut n = 0usize;
        while n < max_rows {
            if self.staged_avail() == 0 {
                if self.remaining_on_disk() == 0 {
                    break;
                }
                self.decode_next_chunk()?;
            }
            let take = (max_rows - n).min(self.staged_avail());
            buf.cols
                .extend_from(&self.staged, self.staged_pos, self.staged_pos + take);
            self.staged_pos += take;
            n += take;
        }
        self.delivered += n as u64;
        Ok(n)
    }
}

// ---------------------------------------------------------------------
// Whole-file scan (tao trace inspect)
// ---------------------------------------------------------------------

/// Full-file walk statistics for a v2 trace (validates every CRC and
/// every section on the way).
pub(crate) struct V2Scan {
    pub name: String,
    pub records: u64,
    pub chunk_rows: u64,
    pub chunks: u64,
    pub payload_bytes: u64,
    pub section_bytes: [u64; 6],
    /// Whether a valid `TAOTFIX1` chunk-offset footer closed the file.
    pub index: bool,
}

pub(crate) fn scan_v2(path: &Path) -> Result<V2Scan> {
    let mut src = CompressedChunkSource::open(path)?;
    let mut scan = V2Scan {
        name: src.name.clone(),
        records: src.declared,
        chunk_rows: src.chunk_rows,
        chunks: 0,
        payload_bytes: 0,
        section_bytes: [0u64; 6],
        index: false,
    };
    while src.remaining_on_disk() > 0 {
        let meta = src.decode_next_chunk()?;
        scan.chunks += 1;
        scan.payload_bytes += meta.payload_len as u64;
        for (total, size) in scan.section_bytes.iter_mut().zip(meta.sections) {
            *total += size as u64;
        }
    }
    // The footer (if any) was consumed and validated by the EOF check
    // on the last chunk (or on open, for an empty trace).
    scan.index = src.saw_index;
    Ok(scan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;
    use crate::workloads;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tao-codec-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(format!("{tag}.trace"))
    }

    fn sample_cols(bench: &str, n: u64) -> TraceColumns {
        let p = workloads::by_name(bench).unwrap().build(7);
        FunctionalSim::new(&p).run(n).to_columns()
    }

    fn roundtrip_u64(vals: &[u64], level: u8) {
        let (enc, data) = encode_u64_column(vals, level);
        let mut out = Vec::new();
        decode_u64_section(enc, &data, vals.len(), &mut out).unwrap();
        assert_eq!(out, vals, "enc {enc} level {level}");
    }

    fn roundtrip_u8(vals: &[u8], level: u8) {
        let (enc, data) = encode_u8_column(vals, level);
        let mut out = Vec::new();
        decode_u8_section(enc, &data, vals.len(), &mut out).unwrap();
        assert_eq!(out, vals, "enc {enc} level {level}");
    }

    #[test]
    fn varint_and_zigzag_reference_vectors() {
        let mut buf = Vec::new();
        push_varint(&mut buf, 0);
        push_varint(&mut buf, 127);
        push_varint(&mut buf, 128);
        push_varint(&mut buf, 300);
        push_varint(&mut buf, u64::MAX);
        assert_eq!(
            buf,
            [
                0x00, // 0
                0x7f, // 127
                0x80, 0x01, // 128
                0xac, 0x02, // 300
                0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x01, // u64::MAX
            ]
        );
        let mut pos = 0;
        for want in [0u64, 127, 128, 300, u64::MAX] {
            assert_eq!(read_varint(&buf, &mut pos).unwrap(), want);
        }
        assert_eq!(pos, buf.len());

        for v in [0i64, 1, -1, 2, -2, i64::MAX, i64::MIN] {
            assert_eq!(unzigzag(zigzag(v)), v);
        }
        assert_eq!(zigzag(0), 0);
        assert_eq!(zigzag(-1), 1);
        assert_eq!(zigzag(1), 2);
    }

    #[test]
    fn varint_rejects_truncation_and_overflow() {
        // Continuation bit set but no next byte.
        let mut pos = 0;
        assert!(read_varint(&[0x80], &mut pos).is_err());
        // 11 continuation bytes: longer than any u64 varint.
        let mut pos = 0;
        assert!(read_varint(&[0x80; 11], &mut pos).is_err());
        // 10 bytes whose top byte overflows 64 bits.
        let mut overflow = vec![0xff; 9];
        overflow.push(0x02);
        let mut pos = 0;
        assert!(read_varint(&overflow, &mut pos).is_err());
    }

    #[test]
    fn u64_encodings_round_trip() {
        let strided: Vec<u64> = (0..1000).map(|i| 0x4000_0000 + i * 4).collect();
        let sparse: Vec<u64> = (0..1000)
            .map(|i| if i % 7 == 0 { 0x1000_0000 + i * 64 } else { 0 })
            .collect();
        let few: Vec<u64> = (0..1000).map(|i| [3u64, 17, 0xff00][i % 3]).collect();
        let wild: Vec<u64> = (0..1000)
            .map(|i| (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15))
            .collect();
        for vals in [&strided, &sparse, &few, &wild] {
            for level in 0..=MAX_LEVEL {
                roundtrip_u64(vals, level);
            }
        }
        // Edges: empty, single, all-equal, extremes.
        for level in 0..=MAX_LEVEL {
            roundtrip_u64(&[], level);
            roundtrip_u64(&[u64::MAX], level);
            roundtrip_u64(&[42; 257], level);
            roundtrip_u64(&[0, u64::MAX, 0, 1, u64::MAX - 1], level);
        }
    }

    #[test]
    fn u8_encodings_round_trip() {
        let runs: Vec<u8> = (0..1000).map(|i| (i / 100) as u8).collect();
        let bits: Vec<u8> = (0..1000).map(|i| (i % 3 == 0) as u8).collect();
        let few: Vec<u8> = (0..1000).map(|i| [0u8, 4, 8][i % 3]).collect();
        // > 15 distinct values exercises the nibble-dict escape path.
        let many: Vec<u8> = (0..1000).map(|i| (i % 37) as u8).collect();
        for vals in [&runs, &bits, &few, &many] {
            for level in 0..=MAX_LEVEL {
                roundtrip_u8(vals, level);
            }
        }
        for level in 0..=MAX_LEVEL {
            roundtrip_u8(&[], level);
            roundtrip_u8(&[255], level);
            roundtrip_u8(&[7; 999], level);
        }
    }

    #[test]
    fn dict64_falls_back_past_256_distinct() {
        let vals: Vec<u64> = (0..300).map(|i| i * 1000).collect();
        assert!(dict64_encode(&vals).is_none());
        // The column encoder still round-trips via another encoding.
        roundtrip_u64(&vals, MAX_LEVEL);
    }

    #[test]
    fn level_zero_stores_raw_sections() {
        let vals: Vec<u64> = (0..100).map(|i| i * 4).collect();
        let (enc, data) = encode_u64_column(&vals, 0);
        assert_eq!(enc, ENC_RAW64);
        assert_eq!(data.len(), 800);
        let bytes: Vec<u8> = (0..100).map(|i| (i % 2) as u8).collect();
        let (enc, data) = encode_u8_column(&bytes, 0);
        assert_eq!(enc, ENC_RAW8);
        assert_eq!(data.len(), 100);
    }

    #[test]
    fn chunk_payload_round_trips_and_rejects_tampering() {
        let cols = sample_cols("dee", 2_000);
        for level in 0..=MAX_LEVEL {
            let payload = encode_chunk_payload(&cols, 0, cols.len(), level);
            let mut back = TraceColumns::new();
            decode_chunk_payload(&payload, cols.len(), &mut back).unwrap();
            assert_eq!(back, cols, "level {level}");
        }
        let payload = encode_chunk_payload(&cols, 0, cols.len(), MAX_LEVEL);
        // Truncated payload fails typed, never panics.
        let mut back = TraceColumns::new();
        assert!(decode_chunk_payload(&payload[..payload.len() - 3], cols.len(), &mut back)
            .is_err());
        // A wrong row count is detected by the section decoders.
        let mut back = TraceColumns::new();
        assert!(decode_chunk_payload(&payload, cols.len() - 1, &mut back).is_err());
        // An unknown encoding tag is rejected.
        let mut bad = payload.clone();
        bad[0] = 0x7f;
        let mut back = TraceColumns::new();
        assert!(decode_chunk_payload(&bad, cols.len(), &mut back).is_err());
    }

    #[test]
    fn writer_bytes_independent_of_append_granularity() {
        let cols = sample_cols("dee", 5_000);
        let all = tmp("grain-all");
        let mut w = V2Writer::create(&all, "dee", 1_024, MAX_LEVEL, true).unwrap();
        w.append(&cols).unwrap();
        assert_eq!(w.finish().unwrap(), 5_000);

        let split = tmp("grain-split");
        let mut w = V2Writer::create(&split, "dee", 1_024, MAX_LEVEL, true).unwrap();
        let mut lo = 0usize;
        for step in [1usize, 700, 99, 1_500, 2_700] {
            let hi = (lo + step).min(cols.len());
            let mut part = TraceColumns::new();
            part.extend_from(&cols, lo, hi);
            w.append(&part).unwrap();
            lo = hi;
        }
        assert_eq!(lo, cols.len());
        w.finish().unwrap();

        assert_eq!(
            std::fs::read(&all).unwrap(),
            std::fs::read(&split).unwrap()
        );
    }

    #[test]
    fn file_round_trips_through_compressed_source() {
        let cols = sample_cols("dee", 10_000);
        let path = tmp("rt");
        let mut w = V2Writer::create(&path, "dee", 4_096, MAX_LEVEL, true).unwrap();
        w.append(&cols).unwrap();
        w.finish().unwrap();

        let mut src = CompressedChunkSource::open(&path).unwrap();
        assert_eq!(src.name(), "dee");
        assert_eq!(src.len_hint(), Some(10_000));
        let mut buf = ChunkBuf::new();
        let mut rebuilt = TraceColumns::new();
        // Consumer chunk size deliberately misaligned with disk chunks.
        while src.next_chunk(&mut buf, 777).unwrap() > 0 {
            rebuilt.extend_from(&buf.cols, 0, buf.len());
        }
        assert_eq!(rebuilt, cols);
        assert_eq!(src.len_hint(), Some(0));

        let scan = scan_v2(&path).unwrap();
        assert_eq!(scan.records, 10_000);
        assert_eq!(scan.chunks, 10_000u64.div_ceil(4_096));
        assert!(scan.payload_bytes > 0);
    }

    #[test]
    fn empty_trace_round_trips() {
        let path = tmp("empty");
        let w = V2Writer::create(&path, "empty", 1_024, MAX_LEVEL, true).unwrap();
        assert_eq!(w.finish().unwrap(), 0);
        let mut src = CompressedChunkSource::open(&path).unwrap();
        assert_eq!(src.len_hint(), Some(0));
        let mut buf = ChunkBuf::new();
        assert_eq!(src.next_chunk(&mut buf, 16).unwrap(), 0);
        // The zero-chunk footer validated on open.
        assert!(src.saw_index);
    }

    #[test]
    fn crc_flip_truncation_and_trailing_bytes_fail_typed() {
        // Index-less file, so the tail cut lands in record data rather
        // than the footer (footer corruption has its own test below).
        let cols = sample_cols("dee", 3_000);
        let path = tmp("tamper");
        let mut w = V2Writer::create(&path, "dee", 1_024, MAX_LEVEL, false).unwrap();
        w.append(&cols).unwrap();
        w.finish().unwrap();
        let good = std::fs::read(&path).unwrap();

        let drain = |path: &Path| -> Result<()> {
            let mut src = CompressedChunkSource::open(path)?;
            let mut buf = ChunkBuf::new();
            while src.next_chunk(&mut buf, 500)? > 0 {}
            Ok(())
        };

        // Flip one byte inside the first chunk's payload (the header is
        // 35 bytes, the chunk frame header 8 more): CRC mismatch, typed.
        let mut bad = good.clone();
        bad[60] ^= 0x40;
        std::fs::write(&path, &bad).unwrap();
        let err = drain(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::CrcMismatch { .. }) | Some(TraceError::CorruptChunk { .. })
            ),
            "unexpected error: {err:#}"
        );

        // Cut the tail: typed truncation.
        std::fs::write(&path, &good[..good.len() - 7]).unwrap();
        let err = drain(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::TruncatedTail { .. })
            ),
            "unexpected error: {err:#}"
        );

        // Trailing bytes after the declared records: typed.
        let mut padded = good.clone();
        padded.extend_from_slice(b"junk");
        std::fs::write(&path, &padded).unwrap();
        let err = drain(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::TrailingGarbage { .. })
            ),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn index_footer_round_trips_and_fails_typed_when_corrupt() {
        let cols = sample_cols("dee", 3_000);
        let path = tmp("footer");
        let mut w = V2Writer::create(&path, "dee", 1_024, MAX_LEVEL, true).unwrap();
        w.append(&cols).unwrap();
        w.finish().unwrap();
        let good = std::fs::read(&path).unwrap();

        let drain = |path: &Path| -> Result<()> {
            let mut src = CompressedChunkSource::open(path)?;
            let mut buf = ChunkBuf::new();
            while src.next_chunk(&mut buf, 500)? > 0 {}
            Ok(())
        };

        // Pristine: drains clean, scan reports the index.
        drain(&path).unwrap();
        let scan = scan_v2(&path).unwrap();
        assert!(scan.index);
        assert_eq!(scan.chunks, 3);

        // The indexed file is exactly the index-less file plus the
        // footer: magic + count + 3 offsets + crc32.
        let noidx = tmp("footer-noidx");
        let mut w = V2Writer::create(&noidx, "dee", 1_024, MAX_LEVEL, false).unwrap();
        w.append(&cols).unwrap();
        w.finish().unwrap();
        assert!(!scan_v2(&noidx).unwrap().index);
        let plain = std::fs::read(&noidx).unwrap();
        assert_eq!(good.len(), plain.len() + 8 + 8 + 3 * 8 + 4);
        assert_eq!(&good[..plain.len()], &plain[..]);

        // Flip a byte inside the footer's offset table: the stream
        // fails typed at EOF.
        let mut bad = good.clone();
        let n = bad.len();
        bad[n - 6] ^= 0x01;
        std::fs::write(&path, &bad).unwrap();
        let err = drain(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::CorruptIndex { .. })
            ),
            "unexpected error: {err:#}"
        );

        // Truncate inside the footer: also a typed corrupt index.
        std::fs::write(&path, &good[..n - 5]).unwrap();
        let err = drain(&path).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::CorruptIndex { .. })
            ),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn compresses_synthetic_traces_well() {
        let cols = sample_cols("dee", 50_000);
        let path = tmp("ratio");
        let mut w = V2Writer::create(&path, "dee", 1 << 16, MAX_LEVEL, true).unwrap();
        w.append(&cols).unwrap();
        w.finish().unwrap();
        let v2_bytes = std::fs::metadata(&path).unwrap().len();
        let v1_bytes = 27 * cols.len() as u64;
        assert!(
            v2_bytes * 4 <= v1_bytes,
            "v2 {v2_bytes} B not >=4x smaller than v1 {v1_bytes} B"
        );
    }
}
