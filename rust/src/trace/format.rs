//! The format-negotiating trace API.
//!
//! Every reader and writer of functional traces goes through this one
//! surface; nothing outside `trace/` looks at magic bytes.
//!
//! * [`open_trace_source`] sniffs the on-disk format and returns a
//!   boxed [`TraceSource`] streaming either `TAOTFNC1` (v1, flat
//!   27 B/instruction) or `TAOTFNC2` (v2, column-compressed) behind
//!   the uniform [`ChunkSource`] pull contract.
//! * [`TraceWriteOptions`] is the builder every writer uses: pick a
//!   [`TraceFormat`], a chunk size and a compression level, then
//!   [`write`](TraceWriteOptions::write) resident columns or stream
//!   through a [`TraceWriter`] with the record count back-patched on
//!   finish.
//! * [`TraceError`] is the typed failure taxonomy shared by both
//!   formats: foreign files are refused by magic (mirroring the serve
//!   cache journal), truncated headers/tails, CRC mismatches and
//!   corrupt chunks each carry their own variant, so callers and tests
//!   can match on the cause instead of grepping message strings.

use super::chunk::{ChunkBuf, ChunkSource, FileChunkSource};
use super::codec::{self, CompressedChunkSource, V2Writer};
use super::columns::TraceColumns;
use super::serialize::{read_func_body_header, write_str, write_u64, MAGIC_FUNC};
use anyhow::{ensure, Context, Result};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// On-disk functional-trace formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceFormat {
    /// `TAOTFNC1`: flat little-endian records, 27 B/instruction.
    V1,
    /// `TAOTFNC2`: column-compressed CRC-framed chunks.
    V2,
}

impl TraceFormat {
    /// The 8-byte magic that opens a file of this format.
    pub fn magic(self) -> &'static [u8; 8] {
        match self {
            TraceFormat::V1 => MAGIC_FUNC,
            TraceFormat::V2 => codec::MAGIC_V2,
        }
    }

    /// CLI-facing name (`"v1"` / `"v2"`).
    pub fn as_str(self) -> &'static str {
        match self {
            TraceFormat::V1 => "v1",
            TraceFormat::V2 => "v2",
        }
    }

    /// Parse a CLI-facing name.
    pub fn parse(s: &str) -> Result<TraceFormat> {
        match s {
            "v1" => Ok(TraceFormat::V1),
            "v2" => Ok(TraceFormat::V2),
            other => anyhow::bail!("unknown trace format {other:?} (expected v1 or v2)"),
        }
    }
}

impl std::fmt::Display for TraceFormat {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Typed trace I/O failures, shared by both formats. Carried inside
/// `anyhow::Error`; callers match with `err.downcast_ref::<TraceError>()`.
#[derive(Debug)]
pub enum TraceError {
    /// The file's magic matches no trace format — a foreign file is
    /// refused outright rather than misread.
    Foreign { path: PathBuf, found: [u8; 8] },
    /// The file ends inside its header.
    TruncatedHeader { path: PathBuf },
    /// A valid trace of the *other* format was handed to a
    /// format-specific reader. `open_trace_source` reads either.
    WrongFormat {
        path: PathBuf,
        found: TraceFormat,
        expected: TraceFormat,
    },
    /// A chunk's framing or content is malformed (v2).
    CorruptChunk {
        path: PathBuf,
        chunk: usize,
        detail: String,
    },
    /// A chunk's CRC32 footer disagrees with its bytes (v2).
    CrcMismatch {
        path: PathBuf,
        chunk: usize,
        stored: u32,
        computed: u32,
    },
    /// The file ends before the declared record count (v2; v1 reports
    /// the failing record through its own decode error).
    TruncatedTail {
        path: PathBuf,
        declared: u64,
        got: u64,
    },
    /// Bytes follow the last declared record.
    TrailingGarbage { path: PathBuf, declared: u64 },
    /// The v2 chunk-offset index footer is present but malformed
    /// (truncated, bad CRC, wrong chunk count, non-monotonic offsets).
    /// Readers that only stream forward never need the index; seek
    /// callers get this typed refusal instead of a mis-seek.
    CorruptIndex { path: PathBuf, detail: String },
}

impl std::fmt::Display for TraceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TraceError::Foreign { path, found } => write!(
                f,
                "{path:?} is not a tao trace (bad magic \"{}\"); refusing to read",
                found.escape_ascii()
            ),
            TraceError::TruncatedHeader { path } => {
                write!(f, "{path:?}: truncated trace header")
            }
            TraceError::WrongFormat {
                path,
                found,
                expected,
            } => write!(
                f,
                "{path:?} is a {found} trace, not {expected}; open_trace_source reads either"
            ),
            TraceError::CorruptChunk {
                path,
                chunk,
                detail,
            } => write!(f, "{path:?}: corrupt chunk {chunk}: {detail}"),
            TraceError::CrcMismatch {
                path,
                chunk,
                stored,
                computed,
            } => write!(
                f,
                "{path:?}: chunk {chunk} CRC mismatch (stored {stored:#010x}, computed {computed:#010x})"
            ),
            TraceError::TruncatedTail {
                path,
                declared,
                got,
            } => write!(
                f,
                "{path:?}: truncated after {got} of {declared} declared records"
            ),
            TraceError::TrailingGarbage { path, declared } => write!(
                f,
                "{path:?}: trailing bytes after the {declared} declared records"
            ),
            TraceError::CorruptIndex { path, detail } => {
                write!(f, "{path:?}: corrupt chunk-offset index: {detail}")
            }
        }
    }
}

impl std::error::Error for TraceError {}

/// Read and classify a trace file's 8-byte magic. A short read is a
/// typed truncated-header error; an unknown magic is a typed foreign-
/// file refusal.
pub(crate) fn read_magic(path: &Path, r: &mut impl Read) -> Result<TraceFormat> {
    let mut magic = [0u8; 8];
    if let Err(e) = r.read_exact(&mut magic) {
        return if e.kind() == std::io::ErrorKind::UnexpectedEof {
            Err(TraceError::TruncatedHeader {
                path: path.to_path_buf(),
            }
            .into())
        } else {
            Err(anyhow::Error::new(e).context(format!("read {path:?}")))
        };
    }
    if &magic == TraceFormat::V1.magic() {
        Ok(TraceFormat::V1)
    } else if &magic == TraceFormat::V2.magic() {
        Ok(TraceFormat::V2)
    } else {
        Err(TraceError::Foreign {
            path: path.to_path_buf(),
            found: magic,
        }
        .into())
    }
}

/// Classify a post-magic header failure: an unexpected EOF becomes the
/// typed truncated-header error, anything else keeps its cause.
pub(crate) fn header_error(path: &Path, e: anyhow::Error) -> anyhow::Error {
    let eof = e
        .downcast_ref::<std::io::Error>()
        .map(|io| io.kind() == std::io::ErrorKind::UnexpectedEof)
        .unwrap_or(false);
    if eof {
        TraceError::TruncatedHeader {
            path: path.to_path_buf(),
        }
        .into()
    } else {
        e.context(format!("{path:?}: bad trace header"))
    }
}

/// Identify a trace file's on-disk format from its magic without
/// reading further.
pub fn sniff_format(path: &Path) -> Result<TraceFormat> {
    let mut file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    read_magic(path, &mut file)
}

/// Read just a trace file's header — format, embedded name, declared
/// record count — without walking the chunks. Both formats share the
/// post-magic header prefix, so this is O(name) work either way; the
/// admission paths (`tao serve`) use it to bound a job before paying
/// for a decode. Failures are the same typed taxonomy as the readers.
pub fn trace_header(path: &Path) -> Result<(TraceFormat, String, u64)> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = std::io::BufReader::new(file);
    let format = read_magic(path, &mut r)?;
    let (name, records) = read_func_body_header(&mut r).map_err(|e| header_error(path, e))?;
    Ok((format, name, records as u64))
}

/// A file-backed chunk stream that knows its provenance: the uniform
/// read surface [`open_trace_source`] returns for either format.
pub trait TraceSource: ChunkSource + Send {
    /// Trace name from the header.
    fn name(&self) -> &str;
    /// The on-disk format being streamed.
    fn format(&self) -> TraceFormat;
    /// Reposition the stream so the next pulled row is `row`, without
    /// decoding the rows before it. v1 is pure offset math; v2 jumps
    /// via the chunk-offset index footer (or a frame-header scan for
    /// index-less files) and decodes at most one chunk. `row` may equal
    /// the record count (positions at EOF); beyond that is an error.
    fn seek_to_row(&mut self, row: u64) -> Result<()>;
}

impl TraceSource for FileChunkSource {
    fn name(&self) -> &str {
        FileChunkSource::name(self)
    }
    fn format(&self) -> TraceFormat {
        TraceFormat::V1
    }
    fn seek_to_row(&mut self, row: u64) -> Result<()> {
        FileChunkSource::seek_to_row(self, row)
    }
}

impl TraceSource for CompressedChunkSource {
    fn name(&self) -> &str {
        CompressedChunkSource::name(self)
    }
    fn format(&self) -> TraceFormat {
        TraceFormat::V2
    }
    fn seek_to_row(&mut self, row: u64) -> Result<()> {
        CompressedChunkSource::seek_to_row(self, row)
    }
}

/// Open a trace file of either format: sniff the magic, dispatch to
/// the right reader, and hand back one [`ChunkSource`]-shaped stream.
/// Decode runs inside `next_chunk`, so wrapping the source in the
/// existing `ChunkPrefetcher` (as the pipelined engine paths do)
/// overlaps file decode with feature staging and model execution.
pub fn open_trace_source(path: &Path) -> Result<Box<dyn TraceSource>> {
    match sniff_format(path)? {
        TraceFormat::V1 => Ok(Box::new(FileChunkSource::open(path)?)),
        TraceFormat::V2 => Ok(Box::new(CompressedChunkSource::open(path)?)),
    }
}

/// How to write a trace: the builder used by every trace writer in the
/// tree. Defaults preserve the historical behavior byte-for-byte
/// (v1, so existing fixtures and oracles keep their hashes).
#[derive(Debug, Clone, Copy)]
pub struct TraceWriteOptions {
    /// On-disk format. Default [`TraceFormat::V1`].
    pub format: TraceFormat,
    /// Rows per v2 chunk (ignored by v1). Default 65 536.
    pub chunk_rows: usize,
    /// v2 compression level, 0..=2 (ignored by v1): 0 stores raw
    /// sections, 1 adds delta/run-length/bit-pack encodings, 2 adds
    /// the dictionary encodings. Default 2.
    pub level: u8,
    /// v2 only: append the `TAOTFIX1` chunk-offset index footer so
    /// readers can seek to a row without scanning frame headers.
    /// Default true; index-less files stay readable and seekable (the
    /// reader falls back to a header-only scan). Ignored by v1, whose
    /// fixed-width rows seek by offset math alone.
    pub index: bool,
}

impl Default for TraceWriteOptions {
    fn default() -> TraceWriteOptions {
        TraceWriteOptions {
            format: TraceFormat::V1,
            chunk_rows: 1 << 16,
            level: codec::MAX_LEVEL,
            index: true,
        }
    }
}

impl TraceWriteOptions {
    /// Options for `format` with default chunking and level.
    pub fn new(format: TraceFormat) -> TraceWriteOptions {
        TraceWriteOptions {
            format,
            ..TraceWriteOptions::default()
        }
    }

    /// Set the format.
    pub fn format(mut self, format: TraceFormat) -> TraceWriteOptions {
        self.format = format;
        self
    }

    /// Set the v2 rows-per-chunk.
    pub fn chunk_rows(mut self, chunk_rows: usize) -> TraceWriteOptions {
        self.chunk_rows = chunk_rows;
        self
    }

    /// Set the v2 compression level (0..=2).
    pub fn level(mut self, level: u8) -> TraceWriteOptions {
        self.level = level;
        self
    }

    /// Enable or disable the v2 chunk-offset index footer.
    pub fn index(mut self, index: bool) -> TraceWriteOptions {
        self.index = index;
        self
    }

    /// Open a streaming [`TraceWriter`] at `path`.
    pub fn writer(&self, path: &Path, name: &str) -> Result<TraceWriter> {
        let inner = match self.format {
            TraceFormat::V1 => WriterInner::V1(V1Writer::create(path, name)?),
            TraceFormat::V2 => WriterInner::V2(V2Writer::create(
                path,
                name,
                self.chunk_rows,
                self.level,
                self.index,
            )?),
        };
        Ok(TraceWriter { inner })
    }

    /// Write resident columns to `path` in one call.
    pub fn write(&self, path: &Path, name: &str, cols: &TraceColumns) -> Result<()> {
        let mut w = self.writer(path, name)?;
        w.append(cols)?;
        w.finish()?;
        Ok(())
    }
}

/// Streaming trace writer for either format. Append columns in any
/// granularity; the record count is back-patched into the header on
/// [`finish`](TraceWriter::finish), and the resulting bytes are
/// independent of how the appends were sliced.
pub struct TraceWriter {
    inner: WriterInner,
}

enum WriterInner {
    V1(V1Writer),
    V2(V2Writer),
}

impl TraceWriter {
    /// Append every record in `cols`.
    pub fn append(&mut self, cols: &TraceColumns) -> Result<()> {
        ensure!(
            cols.is_consistent(),
            "ragged trace columns: {} pcs / {} opcodes / {} bitmaps / {} addrs / {} widths / {} outcomes",
            cols.pc.len(),
            cols.opcode.len(),
            cols.reg_bitmap.len(),
            cols.mem_addr.len(),
            cols.mem_bytes.len(),
            cols.taken.len()
        );
        match &mut self.inner {
            WriterInner::V1(w) => w.append(cols),
            WriterInner::V2(w) => w.append(cols),
        }
    }

    /// Rows appended so far.
    pub fn rows_appended(&self) -> u64 {
        match &self.inner {
            WriterInner::V1(w) => w.written,
            WriterInner::V2(w) => w.rows_appended(),
        }
    }

    /// Flush everything, back-patch the header's record count, and
    /// return the total rows written.
    pub fn finish(self) -> Result<u64> {
        match self.inner {
            WriterInner::V1(w) => w.finish(),
            WriterInner::V2(w) => w.finish(),
        }
    }
}

/// Streaming `TAOTFNC1` writer: byte-identical output to the legacy
/// whole-trace writers, with the record count back-patched on finish so
/// producers can stream without knowing their length up front.
struct V1Writer {
    path: PathBuf,
    w: BufWriter<std::fs::File>,
    count_offset: u64,
    written: u64,
}

impl V1Writer {
    fn create(path: &Path, name: &str) -> Result<V1Writer> {
        let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut w = BufWriter::new(file);
        w.write_all(MAGIC_FUNC)?;
        write_str(&mut w, name)?;
        let count_offset = 8 + 8 + name.len() as u64;
        write_u64(&mut w, 0)?; // record count, back-patched by finish()
        Ok(V1Writer {
            path: path.to_path_buf(),
            w,
            count_offset,
            written: 0,
        })
    }

    fn append(&mut self, cols: &TraceColumns) -> Result<()> {
        for i in 0..cols.len() {
            write_u64(&mut self.w, cols.pc[i])?;
            self.w.write_all(&[cols.opcode[i]])?;
            write_u64(&mut self.w, cols.reg_bitmap[i])?;
            write_u64(&mut self.w, cols.mem_addr[i])?;
            self.w.write_all(&[cols.mem_bytes[i], cols.taken[i]])?;
        }
        self.written += cols.len() as u64;
        Ok(())
    }

    fn finish(mut self) -> Result<u64> {
        self.w
            .flush()
            .with_context(|| format!("flush {:?}", self.path))?;
        let f = self.w.get_mut();
        f.seek(SeekFrom::Start(self.count_offset))
            .and_then(|_| f.write_all(&self.written.to_le_bytes()))
            .with_context(|| format!("back-patch record count in {:?}", self.path))?;
        Ok(self.written)
    }
}

/// Transcode a trace file between formats (or re-chunk/re-level within
/// v2) in O(chunk) memory. Returns the records copied.
pub fn convert_trace(input: &Path, output: &Path, opts: &TraceWriteOptions) -> Result<u64> {
    ensure!(
        input != output,
        "refusing to transcode {input:?} onto itself"
    );
    let mut src = open_trace_source(input)?;
    let name = src.name().to_string();
    let mut w = opts.writer(output, &name)?;
    let mut buf = ChunkBuf::new();
    loop {
        let n = src.next_chunk(&mut buf, 1 << 16)?;
        if n == 0 {
            break;
        }
        w.append(&buf.cols)?;
    }
    w.finish()
}

/// What `inspect_trace` learned about a trace file. Produced by a full
/// validating walk: every record (v1) or chunk CRC + section (v2) has
/// been checked by the time this is returned.
#[derive(Debug)]
pub struct TraceInfo {
    pub format: TraceFormat,
    pub name: String,
    pub records: u64,
    pub file_bytes: u64,
    /// v2 only: nominal rows per chunk.
    pub chunk_rows: Option<u64>,
    /// v2 only: chunk count.
    pub chunks: Option<u64>,
    /// v2 only: encoded bytes per column section, in
    /// `codec::SECTION_NAMES` order.
    pub section_bytes: Option<[u64; 6]>,
    /// v2 only: whether the `TAOTFIX1` chunk-offset index footer is
    /// present (seeks are O(1) instead of a frame-header scan).
    pub index: Option<bool>,
}

impl TraceInfo {
    /// Mean on-disk bytes per instruction (the whole file, headers and
    /// framing included).
    pub fn bytes_per_inst(&self) -> f64 {
        if self.records == 0 {
            0.0
        } else {
            self.file_bytes as f64 / self.records as f64
        }
    }
}

/// Section names for [`TraceInfo::section_bytes`], in on-disk order.
pub fn section_names() -> [&'static str; 6] {
    codec::SECTION_NAMES
}

/// Walk and validate a trace file of either format, returning header
/// facts plus chunk/size statistics.
pub fn inspect_trace(path: &Path) -> Result<TraceInfo> {
    let file_bytes = std::fs::metadata(path)
        .with_context(|| format!("stat {path:?}"))?
        .len();
    match sniff_format(path)? {
        TraceFormat::V1 => {
            let mut src = FileChunkSource::open(path)?;
            let name = FileChunkSource::name(&src).to_string();
            let mut buf = ChunkBuf::new();
            let mut records = 0u64;
            loop {
                let n = src.next_chunk(&mut buf, 1 << 16)?;
                if n == 0 {
                    break;
                }
                records += n as u64;
            }
            Ok(TraceInfo {
                format: TraceFormat::V1,
                name,
                records,
                file_bytes,
                chunk_rows: None,
                chunks: None,
                section_bytes: None,
                index: None,
            })
        }
        TraceFormat::V2 => {
            let scan = codec::scan_v2(path)?;
            Ok(TraceInfo {
                format: TraceFormat::V2,
                name: scan.name,
                records: scan.records,
                file_bytes,
                chunk_rows: Some(scan.chunk_rows),
                chunks: Some(scan.chunks),
                section_bytes: Some(scan.section_bytes),
                index: Some(scan.index),
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;
    use crate::trace::serialize::write_functional_columns;
    use crate::workloads;

    fn tmp(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tao-format-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(format!("{tag}.trace"))
    }

    fn sample_cols(n: u64) -> TraceColumns {
        let p = workloads::by_name("dee").unwrap().build(11);
        FunctionalSim::new(&p).run(n).to_columns()
    }

    fn read_all(path: &Path) -> (String, TraceColumns) {
        let mut src = open_trace_source(path).unwrap();
        let name = src.name().to_string();
        let mut buf = ChunkBuf::new();
        let mut cols = TraceColumns::new();
        loop {
            let n = src.next_chunk(&mut buf, 1 << 12).unwrap();
            if n == 0 {
                break;
            }
            cols.extend_from(&buf.cols, 0, n);
        }
        (name, cols)
    }

    #[test]
    fn sniff_identifies_both_formats_and_refuses_foreign() {
        let cols = sample_cols(100);
        let v1 = tmp("sniff-v1");
        TraceWriteOptions::default().write(&v1, "dee", &cols).unwrap();
        assert_eq!(sniff_format(&v1).unwrap(), TraceFormat::V1);

        let v2 = tmp("sniff-v2");
        TraceWriteOptions::new(TraceFormat::V2)
            .write(&v2, "dee", &cols)
            .unwrap();
        assert_eq!(sniff_format(&v2).unwrap(), TraceFormat::V2);

        let foreign = tmp("sniff-foreign");
        std::fs::write(&foreign, b"NOTATRACE_AT_ALL").unwrap();
        let err = sniff_format(&foreign).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::Foreign { .. })
            ),
            "unexpected error: {err:#}"
        );

        let short = tmp("sniff-short");
        std::fs::write(&short, b"TAO").unwrap();
        let err = sniff_format(&short).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::TruncatedHeader { .. })
            ),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn v1_writer_matches_legacy_writer_bytes() {
        let cols = sample_cols(500);
        let legacy = tmp("legacy");
        write_functional_columns(&legacy, "dee", &cols).unwrap();

        // One-shot write and split appends both match the legacy bytes.
        let oneshot = tmp("oneshot");
        TraceWriteOptions::default()
            .write(&oneshot, "dee", &cols)
            .unwrap();
        assert_eq!(
            std::fs::read(&legacy).unwrap(),
            std::fs::read(&oneshot).unwrap()
        );

        let split = tmp("split");
        let mut w = TraceWriteOptions::default().writer(&split, "dee").unwrap();
        let mut part = TraceColumns::new();
        part.extend_from(&cols, 0, 123);
        w.append(&part).unwrap();
        let mut part = TraceColumns::new();
        part.extend_from(&cols, 123, cols.len());
        w.append(&part).unwrap();
        assert_eq!(w.finish().unwrap(), 500);
        assert_eq!(
            std::fs::read(&legacy).unwrap(),
            std::fs::read(&split).unwrap()
        );
    }

    #[test]
    fn open_trace_source_reads_both_formats_identically() {
        let cols = sample_cols(3_000);
        let v1 = tmp("open-v1");
        let v2 = tmp("open-v2");
        TraceWriteOptions::default().write(&v1, "dee", &cols).unwrap();
        TraceWriteOptions::new(TraceFormat::V2)
            .chunk_rows(1_000)
            .write(&v2, "dee", &cols)
            .unwrap();

        let (n1, c1) = read_all(&v1);
        let (n2, c2) = read_all(&v2);
        assert_eq!(n1, "dee");
        assert_eq!(n2, "dee");
        assert_eq!(c1, cols);
        assert_eq!(c2, cols);

        let s1 = open_trace_source(&v1).unwrap();
        let s2 = open_trace_source(&v2).unwrap();
        assert_eq!(s1.format(), TraceFormat::V1);
        assert_eq!(s2.format(), TraceFormat::V2);
    }

    #[test]
    fn format_specific_readers_reject_the_other_format_typed() {
        let cols = sample_cols(50);
        let v1 = tmp("wrong-v1");
        let v2 = tmp("wrong-v2");
        TraceWriteOptions::default().write(&v1, "dee", &cols).unwrap();
        TraceWriteOptions::new(TraceFormat::V2)
            .write(&v2, "dee", &cols)
            .unwrap();

        let err = CompressedChunkSource::open(&v1).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::WrongFormat {
                    found: TraceFormat::V1,
                    expected: TraceFormat::V2,
                    ..
                })
            ),
            "unexpected error: {err:#}"
        );
        let err = FileChunkSource::open(&v2).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::WrongFormat {
                    found: TraceFormat::V2,
                    expected: TraceFormat::V1,
                    ..
                })
            ),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn convert_round_trips_byte_identically() {
        let cols = sample_cols(2_500);
        let v1 = tmp("conv-v1");
        TraceWriteOptions::default().write(&v1, "dee", &cols).unwrap();

        let v2 = tmp("conv-v2");
        let n = convert_trace(
            &v1,
            &v2,
            &TraceWriteOptions::new(TraceFormat::V2).chunk_rows(777),
        )
        .unwrap();
        assert_eq!(n, 2_500);

        // v1 -> v2 -> v1 reproduces the original file exactly.
        let back = tmp("conv-back");
        convert_trace(&back, &back, &TraceWriteOptions::default()).unwrap_err();
        let n = convert_trace(&v2, &back, &TraceWriteOptions::default()).unwrap();
        assert_eq!(n, 2_500);
        assert_eq!(std::fs::read(&v1).unwrap(), std::fs::read(&back).unwrap());
    }

    #[test]
    fn inspect_reports_both_formats() {
        let cols = sample_cols(4_000);
        let v1 = tmp("insp-v1");
        let v2 = tmp("insp-v2");
        TraceWriteOptions::default().write(&v1, "dee", &cols).unwrap();
        TraceWriteOptions::new(TraceFormat::V2)
            .chunk_rows(1_024)
            .write(&v2, "dee", &cols)
            .unwrap();

        let i1 = inspect_trace(&v1).unwrap();
        assert_eq!(i1.format, TraceFormat::V1);
        assert_eq!(i1.name, "dee");
        assert_eq!(i1.records, 4_000);
        assert!(i1.bytes_per_inst() > 27.0); // 27 B/record + header
        assert!(i1.chunks.is_none());

        let i2 = inspect_trace(&v2).unwrap();
        assert_eq!(i2.format, TraceFormat::V2);
        assert_eq!(i2.name, "dee");
        assert_eq!(i2.records, 4_000);
        assert_eq!(i2.chunk_rows, Some(1_024));
        assert_eq!(i2.chunks, Some(4_000u64.div_ceil(1_024)));
        let sections = i2.section_bytes.unwrap();
        assert!(sections.iter().all(|&b| b > 0));
        assert!(i2.bytes_per_inst() < i1.bytes_per_inst());
        assert!(i1.index.is_none());
        assert_eq!(i2.index, Some(true));

        let noidx = tmp("insp-noidx");
        TraceWriteOptions::new(TraceFormat::V2)
            .chunk_rows(1_024)
            .index(false)
            .write(&noidx, "dee", &cols)
            .unwrap();
        let i3 = inspect_trace(&noidx).unwrap();
        assert_eq!(i3.records, 4_000);
        assert_eq!(i3.index, Some(false));
    }

    #[test]
    fn seek_to_row_matches_decode_from_start_both_formats() {
        let cols = sample_cols(3_000);
        let v1 = tmp("seek-v1");
        let v2 = tmp("seek-v2");
        let noidx = tmp("seek-noidx");
        TraceWriteOptions::default().write(&v1, "dee", &cols).unwrap();
        TraceWriteOptions::new(TraceFormat::V2)
            .chunk_rows(700)
            .write(&v2, "dee", &cols)
            .unwrap();
        TraceWriteOptions::new(TraceFormat::V2)
            .chunk_rows(700)
            .index(false)
            .write(&noidx, "dee", &cols)
            .unwrap();

        for path in [&v1, &v2, &noidx] {
            for row in [0u64, 1, 699, 700, 701, 1_399, 2_345, 2_999] {
                let mut src = open_trace_source(path).unwrap();
                src.seek_to_row(row).unwrap();
                let mut buf = ChunkBuf::new();
                let mut got = TraceColumns::new();
                loop {
                    let n = src.next_chunk(&mut buf, 512).unwrap();
                    if n == 0 {
                        break;
                    }
                    got.extend_from(&buf.cols, 0, n);
                }
                let mut want = TraceColumns::new();
                want.extend_from(&cols, row as usize, cols.len());
                assert_eq!(got, want, "{path:?} seek to {row}");
            }

            // Seeking to the record count positions at EOF, and a
            // drained source can seek backwards and keep reading.
            let mut src = open_trace_source(path).unwrap();
            src.seek_to_row(3_000).unwrap();
            let mut buf = ChunkBuf::new();
            assert_eq!(src.next_chunk(&mut buf, 64).unwrap(), 0);
            src.seek_to_row(2_999).unwrap();
            assert_eq!(src.next_chunk(&mut buf, 64).unwrap(), 1);
            assert_eq!(buf.cols.pc[0], cols.pc[2_999]);
            src.seek_to_row(5).unwrap();
            assert_eq!(src.next_chunk(&mut buf, 1).unwrap(), 1);
            assert_eq!(buf.cols.pc[0], cols.pc[5]);

            // Past the record count is an error.
            src.seek_to_row(3_001).unwrap_err();
        }
    }

    #[test]
    fn corrupt_index_footer_fails_typed_on_seek() {
        let cols = sample_cols(2_000);
        let v2 = tmp("seek-corrupt");
        TraceWriteOptions::new(TraceFormat::V2)
            .chunk_rows(512)
            .write(&v2, "dee", &cols)
            .unwrap();
        let mut bytes = std::fs::read(&v2).unwrap();
        let n = bytes.len();
        // Flip a bit inside the footer's offset table: the magic still
        // matches, so seeks must fail with the typed corrupt-index
        // error rather than mis-seek.
        bytes[n - 12] ^= 0x01;
        std::fs::write(&v2, &bytes).unwrap();
        let mut src = open_trace_source(&v2).unwrap();
        let err = src.seek_to_row(1_500).unwrap_err();
        assert!(
            matches!(
                err.downcast_ref::<TraceError>(),
                Some(TraceError::CorruptIndex { .. })
            ),
            "unexpected error: {err:#}"
        );
    }

    #[test]
    fn trace_header_peeks_both_formats_typed() {
        let cols = sample_cols(500);
        let v1 = tmp("hdr-v1");
        TraceWriteOptions::default().write(&v1, "hdr1", &cols).unwrap();
        assert_eq!(
            trace_header(&v1).unwrap(),
            (TraceFormat::V1, "hdr1".to_string(), 500)
        );
        let v2 = tmp("hdr-v2");
        TraceWriteOptions::new(TraceFormat::V2)
            .write(&v2, "hdr2", &cols)
            .unwrap();
        assert_eq!(
            trace_header(&v2).unwrap(),
            (TraceFormat::V2, "hdr2".to_string(), 500)
        );
        let foreign = tmp("hdr-foreign");
        std::fs::write(&foreign, b"NOTATRACE!!!").unwrap();
        let err = trace_header(&foreign).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<TraceError>(),
            Some(TraceError::Foreign { .. })
        ));
    }
}
