//! Binary trace serialization (format `TAOT` v1).
//!
//! A purpose-built little-endian binary format: traces at paper scale run
//! to hundreds of millions of records, so the writer/reader stream through
//! `BufWriter`/`BufReader` without intermediate allocation. A text dump is
//! available via `Display` on records for debugging; the binary format is
//! the interchange between the `tao datagen` step and everything else.

use super::columns::TraceColumns;
use super::record::{
    AccessLevel, DetailedRecord, DetailedTrace, FuncRecord, FunctionalTrace, RetiredInfo,
};
use crate::isa::Opcode;
use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

pub(crate) const MAGIC_FUNC: &[u8; 8] = b"TAOTFNC1";
const MAGIC_DET: &[u8; 8] = b"TAOTDET1";

const TAG_RETIRED: u8 = 0;
const TAG_SQUASHED: u8 = 1;
const TAG_NOP: u8 = 2;

pub(crate) fn write_u64(w: &mut impl Write, v: u64) -> Result<()> {
    w.write_all(&v.to_le_bytes())?;
    Ok(())
}

pub(crate) fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    write_u64(w, s.len() as u64)?;
    w.write_all(s.as_bytes())?;
    Ok(())
}

pub(crate) fn read_u64(r: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

pub(crate) fn read_str(r: &mut impl Read) -> Result<String> {
    let len = read_u64(r)? as usize;
    ensure!(len < 1 << 20, "unreasonable string length {len}");
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    Ok(String::from_utf8(buf)?)
}

fn write_func_record(w: &mut impl Write, rec: &FuncRecord) -> Result<()> {
    write_u64(w, rec.pc)?;
    w.write_all(&[rec.opcode.index() as u8])?;
    write_u64(w, rec.reg_bitmap)?;
    write_u64(w, rec.mem_addr)?;
    w.write_all(&[rec.mem_bytes, rec.taken as u8])?;
    Ok(())
}

/// Decode one functional record's raw fields (the columnar/streaming
/// readers append these straight to their columns; [`read_func_record`]
/// assembles them). Opcode ids are validated here so every reader shares
/// the check.
pub(crate) fn read_func_fields(
    r: &mut impl Read,
) -> Result<(u64, u8, u64, u64, u8, bool)> {
    let pc = read_u64(r)?;
    let op = read_u8(r)?;
    ensure!((op as usize) < Opcode::COUNT, "bad opcode id {op}");
    let reg_bitmap = read_u64(r)?;
    let mem_addr = read_u64(r)?;
    let mem_bytes = read_u8(r)?;
    let taken = read_u8(r)? != 0;
    Ok((pc, op, reg_bitmap, mem_addr, mem_bytes, taken))
}

/// Read + validate a `TAOTFNC1` header, returning the trace name and
/// declared record count. The count is a claim about the payload, not a
/// preallocation size — readers cap their reserves so a corrupt header
/// cannot trigger an allocation abort.
pub(crate) fn read_func_header(r: &mut impl Read) -> Result<(String, usize)> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC_FUNC, "not a functional trace: bad magic");
    read_func_body_header(r)
}

/// Read the post-magic part of a `TAOTFNC1` header (name + declared
/// count). [`FileChunkSource`](crate::trace::chunk::FileChunkSource)
/// classifies the magic itself (through the typed
/// [`TraceError`](crate::trace::format::TraceError) taxonomy) and then
/// calls this.
pub(crate) fn read_func_body_header(r: &mut impl Read) -> Result<(String, usize)> {
    let name = read_str(r)?;
    let n = read_u64(r)?;
    ensure!(
        usize::try_from(n).is_ok(),
        "unrepresentable record count {n}"
    );
    Ok((name, n as usize))
}

fn read_func_record(r: &mut impl Read) -> Result<FuncRecord> {
    let (pc, op, reg_bitmap, mem_addr, mem_bytes, taken) = read_func_fields(r)?;
    Ok(FuncRecord {
        pc,
        opcode: Opcode::from_index(op as usize),
        reg_bitmap,
        mem_addr,
        mem_bytes,
        taken,
    })
}

/// Write a functional trace to `path`.
pub fn write_functional(path: &Path, trace: &FunctionalTrace) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC_FUNC)?;
    write_str(&mut w, &trace.name)?;
    write_u64(&mut w, trace.records.len() as u64)?;
    for rec in &trace.records {
        write_func_record(&mut w, rec)?;
    }
    w.flush()?;
    Ok(())
}

/// Read a functional trace from `path`.
pub fn read_functional(path: &Path) -> Result<FunctionalTrace> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(file);
    let (name, n) = read_func_header(&mut r)?;
    // Capped reserve: a corrupt header count must error on decode, not
    // abort on allocation.
    let mut records = Vec::with_capacity(n.min(1 << 22));
    for i in 0..n {
        records.push(
            read_func_record(&mut r).with_context(|| format!("record {i} of {n}"))?,
        );
    }
    // Same EOF probe as the chunked reader: both readers of the format
    // must agree on what a valid file is.
    let mut probe = [0u8; 1];
    ensure!(
        r.read(&mut probe)? == 0,
        "trailing bytes after the {n} declared records"
    );
    Ok(FunctionalTrace { name, records })
}

/// Write a columnar functional trace to `path` as `TAOTFNC1`. Thin
/// wrapper kept for existing callers — new code should go through
/// [`TraceWriteOptions`](crate::trace::format::TraceWriteOptions),
/// which picks the format (the default reproduces this writer's bytes
/// exactly, so AoS and SoA producers/consumers keep interoperating).
pub fn write_functional_columns(path: &Path, name: &str, cols: &TraceColumns) -> Result<()> {
    crate::trace::format::TraceWriteOptions::default().write(path, name, cols)
}

/// Read a functional trace of either on-disk format from `path`
/// directly into columnar storage — no intermediate `Vec<FuncRecord>`
/// is materialized. Thin wrapper kept for existing callers: an
/// accumulation loop over
/// [`open_trace_source`](crate::trace::format::open_trace_source), so
/// the whole-file and streaming readers share one decode + validation
/// path (truncated tails, CRC mismatches, bad opcode ids and trailing
/// garbage all error).
pub fn read_functional_columns(path: &Path) -> Result<(String, TraceColumns)> {
    use crate::trace::chunk::{ChunkBuf, ChunkSource};
    use crate::trace::format::TraceSource;
    let mut src = crate::trace::format::open_trace_source(path)?;
    let mut cols = TraceColumns::with_capacity(src.len_hint().unwrap_or(0).min(1 << 22));
    let mut buf = ChunkBuf::new();
    loop {
        let n = src.next_chunk(&mut buf, 1 << 16)?;
        if n == 0 {
            break;
        }
        cols.extend_from(&buf.cols, 0, n);
    }
    Ok((src.name().to_string(), cols))
}

/// Write a detailed trace to `path`.
pub fn write_detailed(path: &Path, trace: &DetailedTrace) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC_DET)?;
    write_str(&mut w, &trace.name)?;
    write_str(&mut w, &trace.uarch)?;
    write_u64(&mut w, trace.total_cycles)?;
    write_u64(&mut w, trace.records.len() as u64)?;
    for rec in &trace.records {
        match rec {
            DetailedRecord::Retired(info) => {
                w.write_all(&[TAG_RETIRED])?;
                write_func_record(&mut w, &info.func)?;
                write_u64(&mut w, info.fetch_clock)?;
                write_u64(&mut w, info.retire_clock)?;
                w.write_all(&[
                    info.branch_mispred as u8,
                    info.access_level.index() as u8,
                    info.icache_miss as u8,
                    info.tlb_miss as u8,
                ])?;
            }
            DetailedRecord::Squashed {
                pc,
                opcode,
                fetch_clock,
            } => {
                w.write_all(&[TAG_SQUASHED])?;
                write_u64(&mut w, *pc)?;
                w.write_all(&[opcode.index() as u8])?;
                write_u64(&mut w, *fetch_clock)?;
            }
            DetailedRecord::NopStall { fetch_clock } => {
                w.write_all(&[TAG_NOP])?;
                write_u64(&mut w, *fetch_clock)?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a detailed trace from `path`.
pub fn read_detailed(path: &Path) -> Result<DetailedTrace> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic == MAGIC_DET, "not a detailed trace: bad magic");
    let name = read_str(&mut r)?;
    let uarch = read_str(&mut r)?;
    let total_cycles = read_u64(&mut r)?;
    let n = read_u64(&mut r)? as usize;
    let mut records = Vec::with_capacity(n);
    for _ in 0..n {
        let tag = read_u8(&mut r)?;
        let rec = match tag {
            TAG_RETIRED => {
                let func = read_func_record(&mut r)?;
                let fetch_clock = read_u64(&mut r)?;
                let retire_clock = read_u64(&mut r)?;
                let branch_mispred = read_u8(&mut r)? != 0;
                let level = read_u8(&mut r)? as usize;
                ensure!(level < AccessLevel::COUNT, "bad access level {level}");
                let icache_miss = read_u8(&mut r)? != 0;
                let tlb_miss = read_u8(&mut r)? != 0;
                DetailedRecord::Retired(RetiredInfo {
                    func,
                    fetch_clock,
                    retire_clock,
                    branch_mispred,
                    access_level: AccessLevel::from_index(level),
                    icache_miss,
                    tlb_miss,
                })
            }
            TAG_SQUASHED => {
                let pc = read_u64(&mut r)?;
                let op = read_u8(&mut r)? as usize;
                ensure!(op < Opcode::COUNT, "bad opcode id {op}");
                let fetch_clock = read_u64(&mut r)?;
                DetailedRecord::Squashed {
                    pc,
                    opcode: Opcode::from_index(op),
                    fetch_clock,
                }
            }
            TAG_NOP => DetailedRecord::NopStall {
                fetch_clock: read_u64(&mut r)?,
            },
            _ => bail!("bad record tag {tag}"),
        };
        records.push(rec);
    }
    Ok(DetailedTrace {
        name,
        uarch,
        records,
        total_cycles,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::Opcode;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tao-test-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn sample_functional() -> FunctionalTrace {
        FunctionalTrace {
            name: "mcf".into(),
            records: vec![
                FuncRecord {
                    pc: 0x400000,
                    opcode: Opcode::Ldr,
                    reg_bitmap: 0b11,
                    mem_addr: 0x10000040,
                    mem_bytes: 8,
                    taken: false,
                },
                FuncRecord {
                    pc: 0x400004,
                    opcode: Opcode::Bcond,
                    reg_bitmap: 0b100,
                    mem_addr: 0,
                    mem_bytes: 0,
                    taken: true,
                },
            ],
        }
    }

    #[test]
    fn functional_round_trip() {
        let path = tmpdir().join("func_rt.trace");
        let t = sample_functional();
        write_functional(&path, &t).unwrap();
        let back = read_functional(&path).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn detailed_round_trip() {
        let path = tmpdir().join("det_rt.trace");
        let t = DetailedTrace {
            name: "mcf".into(),
            uarch: "uarch_a".into(),
            total_cycles: 99,
            records: vec![
                DetailedRecord::Retired(RetiredInfo {
                    func: sample_functional().records[0],
                    fetch_clock: 1,
                    retire_clock: 9,
                    branch_mispred: false,
                    access_level: AccessLevel::L2,
                    icache_miss: true,
                    tlb_miss: false,
                }),
                DetailedRecord::Squashed {
                    pc: 0x400008,
                    opcode: Opcode::Add,
                    fetch_clock: 2,
                },
                DetailedRecord::NopStall { fetch_clock: 3 },
            ],
        };
        write_detailed(&path, &t).unwrap();
        let back = read_detailed(&path).unwrap();
        assert_eq!(back.name, t.name);
        assert_eq!(back.uarch, t.uarch);
        assert_eq!(back.total_cycles, t.total_cycles);
        assert_eq!(back.records, t.records);
    }

    #[test]
    fn wrong_magic_rejected() {
        let dir = tmpdir();
        let fpath = dir.join("f.trace");
        let dpath = dir.join("d.trace");
        write_functional(&fpath, &sample_functional()).unwrap();
        assert!(read_detailed(&fpath).is_err());
        let dt = DetailedTrace {
            name: "x".into(),
            uarch: "a".into(),
            ..Default::default()
        };
        write_detailed(&dpath, &dt).unwrap();
        assert!(read_functional(&dpath).is_err());
    }

    #[test]
    fn truncated_file_errors() {
        let path = tmpdir().join("trunc.trace");
        write_functional(&path, &sample_functional()).unwrap();
        let data = std::fs::read(&path).unwrap();
        std::fs::write(&path, &data[..data.len() - 4]).unwrap();
        assert!(read_functional(&path).is_err());
        // Trailing garbage is rejected by both readers of the format.
        let mut padded = data.clone();
        padded.extend_from_slice(b"junk");
        std::fs::write(&path, &padded).unwrap();
        assert!(read_functional(&path).is_err());
        assert!(read_functional_columns(&path).is_err());
    }

    #[test]
    fn columnar_and_aos_formats_interoperate() {
        let dir = tmpdir();
        let t = sample_functional();
        let cols = t.to_columns();

        // SoA writer -> AoS reader.
        let p1 = dir.join("soa_write.trace");
        write_functional_columns(&p1, &t.name, &cols).unwrap();
        assert_eq!(read_functional(&p1).unwrap(), t);
        // Byte-identical to the AoS writer.
        let p2 = dir.join("aos_write.trace");
        write_functional(&p2, &t).unwrap();
        assert_eq!(std::fs::read(&p1).unwrap(), std::fs::read(&p2).unwrap());

        // AoS writer -> SoA reader.
        let (name, cols2) = read_functional_columns(&p2).unwrap();
        assert_eq!(name, t.name);
        assert_eq!(cols2, cols);
    }

    #[test]
    fn columnar_reader_rejects_detailed_magic() {
        let dir = tmpdir();
        let dpath = dir.join("det_for_cols.trace");
        let dt = DetailedTrace {
            name: "x".into(),
            uarch: "a".into(),
            ..Default::default()
        };
        write_detailed(&dpath, &dt).unwrap();
        assert!(read_functional_columns(&dpath).is_err());
    }

    #[test]
    fn empty_trace_round_trips() {
        let path = tmpdir().join("empty.trace");
        let t = FunctionalTrace {
            name: "empty".into(),
            records: vec![],
        };
        write_functional(&path, &t).unwrap();
        assert_eq!(read_functional(&path).unwrap(), t);
    }
}
