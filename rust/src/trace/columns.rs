//! Structure-of-arrays trace storage.
//!
//! [`FuncRecord`] is a 32-byte struct (27 payload bytes padded to
//! alignment); a `Vec<FuncRecord>` interleaves every field of every
//! instruction, so the inference hot path — which reads mostly
//! `pc`/`opcode`/`taken` for branches and `mem_addr` for memory ops —
//! drags the whole record through the cache per touch. [`TraceColumns`]
//! stores one densely-packed `Vec` per field instead:
//!
//! * sequential feature extraction streams each column at full cache-line
//!   utilization (27 bytes/instruction, no padding, and each scan touches
//!   only the columns it needs);
//! * trace (de)serialization becomes straight column appends with no
//!   intermediate record materialization (`trace::serialize`
//!   `read_functional_columns`);
//! * shards are cheap range views (`slice`) — no copying on partition.
//!
//! `record(i)` assembles a [`FuncRecord`] from the columns in registers;
//! it is the bridge for code that still wants AoS views and costs a few
//! loads, not an allocation.

use super::record::{FuncRecord, FunctionalTrace};
use crate::isa::Opcode;

/// Columnar (structure-of-arrays) functional-trace storage.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceColumns {
    /// Program counters.
    pub pc: Vec<u64>,
    /// Opcode ids (`Opcode::index()`; the ISA has < 256 opcodes, matching
    /// the on-disk u8 encoding).
    pub opcode: Vec<u8>,
    /// Register bitmaps.
    pub reg_bitmap: Vec<u64>,
    /// Effective memory addresses (0 for non-memory ops).
    pub mem_addr: Vec<u64>,
    /// Access widths in bytes (0 for non-memory ops).
    pub mem_bytes: Vec<u8>,
    /// Branch outcomes (0/1; 0 for non-branches).
    pub taken: Vec<u8>,
}

impl TraceColumns {
    /// Empty columns.
    pub fn new() -> TraceColumns {
        TraceColumns::default()
    }

    /// Empty columns with per-field capacity for `n` records.
    pub fn with_capacity(n: usize) -> TraceColumns {
        TraceColumns {
            pc: Vec::with_capacity(n),
            opcode: Vec::with_capacity(n),
            reg_bitmap: Vec::with_capacity(n),
            mem_addr: Vec::with_capacity(n),
            mem_bytes: Vec::with_capacity(n),
            taken: Vec::with_capacity(n),
        }
    }

    /// Number of instructions.
    pub fn len(&self) -> usize {
        self.pc.len()
    }

    /// True if no instructions are stored.
    pub fn is_empty(&self) -> bool {
        self.pc.is_empty()
    }

    /// Append one record (fields fan out to their columns).
    pub fn push(&mut self, rec: &FuncRecord) {
        self.push_fields(
            rec.pc,
            rec.opcode.index() as u8,
            rec.reg_bitmap,
            rec.mem_addr,
            rec.mem_bytes,
            rec.taken,
        );
    }

    /// Append one record given raw field values (the deserializer's
    /// entry point — no `FuncRecord` is materialized).
    pub fn push_fields(
        &mut self,
        pc: u64,
        opcode_id: u8,
        reg_bitmap: u64,
        mem_addr: u64,
        mem_bytes: u8,
        taken: bool,
    ) {
        self.pc.push(pc);
        self.opcode.push(opcode_id);
        self.reg_bitmap.push(reg_bitmap);
        self.mem_addr.push(mem_addr);
        self.mem_bytes.push(mem_bytes);
        self.taken.push(taken as u8);
    }

    /// Assemble the `i`-th record from the columns (register-level work,
    /// no allocation).
    #[inline]
    pub fn record(&self, i: usize) -> FuncRecord {
        FuncRecord {
            pc: self.pc[i],
            opcode: Opcode::from_index(self.opcode[i] as usize),
            reg_bitmap: self.reg_bitmap[i],
            mem_addr: self.mem_addr[i],
            mem_bytes: self.mem_bytes[i],
            taken: self.taken[i] != 0,
        }
    }

    /// Build columns from an AoS record slice.
    pub fn from_records(records: &[FuncRecord]) -> TraceColumns {
        let mut cols = TraceColumns::with_capacity(records.len());
        for rec in records {
            cols.push(rec);
        }
        cols
    }

    /// Materialize an AoS record vector (tests / compatibility).
    pub fn to_records(&self) -> Vec<FuncRecord> {
        (0..self.len()).map(|i| self.record(i)).collect()
    }

    /// Iterate assembled records.
    pub fn iter(&self) -> impl Iterator<Item = FuncRecord> + '_ {
        (0..self.len()).map(move |i| self.record(i))
    }

    /// Drop all records, keeping the allocations (chunk-buffer reuse).
    pub fn clear(&mut self) {
        self.pc.clear();
        self.opcode.clear();
        self.reg_bitmap.clear();
        self.mem_addr.clear();
        self.mem_bytes.clear();
        self.taken.clear();
    }

    /// Keep only the first `n` records.
    pub fn truncate(&mut self, n: usize) {
        self.pc.truncate(n);
        self.opcode.truncate(n);
        self.reg_bitmap.truncate(n);
        self.mem_addr.truncate(n);
        self.mem_bytes.truncate(n);
        self.taken.truncate(n);
    }

    /// Append `other[lo..hi)` column-wise (chunk concatenation; straight
    /// `Vec` extends, no record assembly).
    pub fn extend_from(&mut self, other: &TraceColumns, lo: usize, hi: usize) {
        assert!(lo <= hi && hi <= other.len(), "bad extend range {lo}..{hi}");
        self.pc.extend_from_slice(&other.pc[lo..hi]);
        self.opcode.extend_from_slice(&other.opcode[lo..hi]);
        self.reg_bitmap.extend_from_slice(&other.reg_bitmap[lo..hi]);
        self.mem_addr.extend_from_slice(&other.mem_addr[lo..hi]);
        self.mem_bytes.extend_from_slice(&other.mem_bytes[lo..hi]);
        self.taken.extend_from_slice(&other.taken[lo..hi]);
    }

    /// True if every column holds the same record count (writers reject
    /// ragged columns instead of panicking mid-serialization).
    pub fn is_consistent(&self) -> bool {
        let n = self.pc.len();
        self.opcode.len() == n
            && self.reg_bitmap.len() == n
            && self.mem_addr.len() == n
            && self.mem_bytes.len() == n
            && self.taken.len() == n
    }

    /// Borrowed range view `[lo, hi)` — the zero-copy shard primitive.
    pub fn slice(&self, lo: usize, hi: usize) -> ColumnsSlice<'_> {
        assert!(lo <= hi && hi <= self.len(), "bad slice {lo}..{hi}");
        ColumnsSlice {
            cols: self,
            lo,
            hi,
        }
    }

    /// Heap bytes held by the columns (diagnostics; 27 B/instruction vs
    /// the padded `Vec<FuncRecord>` stride).
    pub fn heap_bytes(&self) -> usize {
        self.pc.len() * 8
            + self.opcode.len()
            + self.reg_bitmap.len() * 8
            + self.mem_addr.len() * 8
            + self.mem_bytes.len()
            + self.taken.len()
    }
}

impl FunctionalTrace {
    /// Convert the record stream to columnar storage.
    pub fn to_columns(&self) -> TraceColumns {
        TraceColumns::from_records(&self.records)
    }
}

/// A borrowed `[lo, hi)` view over [`TraceColumns`].
#[derive(Debug, Clone, Copy)]
pub struct ColumnsSlice<'a> {
    cols: &'a TraceColumns,
    lo: usize,
    hi: usize,
}

impl<'a> ColumnsSlice<'a> {
    /// Instructions in the view.
    pub fn len(&self) -> usize {
        self.hi - self.lo
    }

    /// True if the view is empty.
    pub fn is_empty(&self) -> bool {
        self.lo == self.hi
    }

    /// Assemble the `i`-th record of the view.
    #[inline]
    pub fn record(&self, i: usize) -> FuncRecord {
        debug_assert!(i < self.len());
        self.cols.record(self.lo + i)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::functional::FunctionalSim;
    use crate::workloads;

    fn sample_trace(n: u64) -> FunctionalTrace {
        let p = workloads::by_name("dee").unwrap().build(3);
        FunctionalSim::new(&p).run(n)
    }

    #[test]
    fn round_trips_records() {
        let t = sample_trace(2_000);
        let cols = t.to_columns();
        assert_eq!(cols.len(), t.records.len());
        assert_eq!(cols.to_records(), t.records);
        for (i, rec) in t.records.iter().enumerate() {
            assert_eq!(&cols.record(i), rec);
        }
    }

    #[test]
    fn iter_matches_records() {
        let t = sample_trace(500);
        let cols = t.to_columns();
        let collected: Vec<FuncRecord> = cols.iter().collect();
        assert_eq!(collected, t.records);
    }

    #[test]
    fn slice_views_are_offsets() {
        let t = sample_trace(300);
        let cols = t.to_columns();
        let s = cols.slice(100, 250);
        assert_eq!(s.len(), 150);
        assert_eq!(s.record(0), t.records[100]);
        assert_eq!(s.record(149), t.records[249]);
    }

    #[test]
    fn heap_bytes_smaller_than_aos() {
        let t = sample_trace(4_000);
        let cols = t.to_columns();
        let aos = t.records.len() * std::mem::size_of::<FuncRecord>();
        assert!(
            cols.heap_bytes() < aos,
            "SoA {} should be denser than AoS {}",
            cols.heap_bytes(),
            aos
        );
    }

    #[test]
    fn clear_truncate_extend_round_trip() {
        let t = sample_trace(400);
        let cols = t.to_columns();
        let mut acc = TraceColumns::new();
        acc.extend_from(&cols, 0, 150);
        acc.extend_from(&cols, 150, 400);
        assert_eq!(acc, cols);
        assert!(acc.is_consistent());
        acc.truncate(100);
        assert_eq!(acc.len(), 100);
        assert_eq!(acc.record(99), t.records[99]);
        acc.clear();
        assert!(acc.is_empty() && acc.is_consistent());
        // Ragged columns are detectable.
        let mut ragged = cols.clone();
        ragged.pc.pop();
        assert!(!ragged.is_consistent());
    }

    #[test]
    fn empty_columns() {
        let cols = TraceColumns::new();
        assert!(cols.is_empty());
        assert_eq!(cols.heap_bytes(), 0);
        assert!(cols.to_records().is_empty());
    }
}
