//! Minimal NumPy `.npy` (format v1.0) writer/reader.
//!
//! The interchange between `tao datagen` (Rust) and the build-time
//! training stack (Python) is plain `.npy` arrays — features, opcode ids
//! and labels — so the Python side is just `np.load`. Supports the three
//! dtypes the pipeline needs: `f32`, `i32`, `i64`, in 1-D and 2-D
//! C-contiguous layouts, plus an incremental [`NpyWriter`] that appends
//! rows chunk by chunk and back-patches the final shape on finalize —
//! the bounded-memory path behind streaming datagen.

use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

const MAGIC: &[u8] = b"\x93NUMPY\x01\x00";

/// On-disk size of every v1.0 header this module emits: magic (8) +
/// length field (2) + dict padded to the next multiple of 64. The dict
/// is 53 bytes + the shape string + newline on top of the 10-byte
/// prefix, so for the 3-character descrs used here and any shape string
/// under 64 bytes (that covers 20-digit row counts) the total always
/// pads to exactly 128 bytes. That fixed size is what lets [`NpyWriter`]
/// reserve the header up front and rewrite it in place on finalize
/// without moving the payload — byte-identical to a one-shot write.
const HEADER_BLOCK: usize = 128;

/// Supported element types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// little-endian f32 (`<f4`)
    F32,
    /// little-endian i32 (`<i4`)
    I32,
    /// little-endian i64 (`<i8`)
    I64,
}

impl Dtype {
    fn descr(self) -> &'static str {
        match self {
            Dtype::F32 => "<f4",
            Dtype::I32 => "<i4",
            Dtype::I64 => "<i8",
        }
    }

    fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I64 => 8,
        }
    }

    fn from_descr(s: &str) -> Result<Dtype> {
        match s {
            "<f4" => Ok(Dtype::F32),
            "<i4" => Ok(Dtype::I32),
            "<i8" => Ok(Dtype::I64),
            _ => bail!("unsupported npy dtype {s:?}"),
        }
    }
}

fn write_header(w: &mut impl Write, dtype: Dtype, shape: &[usize]) -> Result<()> {
    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
        dtype.descr(),
        shape_str
    );
    // Pad so that magic(8) + len(2) + header is a multiple of 64.
    let unpadded = MAGIC.len() + 2 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    w.write_all(MAGIC)?;
    w.write_all(&(header.len() as u16).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    Ok(())
}

fn write_array(path: &Path, dtype: Dtype, shape: &[usize], bytes: &[u8]) -> Result<()> {
    let n: usize = shape.iter().product();
    ensure!(
        bytes.len() == n * dtype.size(),
        "shape {:?} needs {} bytes, got {}",
        shape,
        n * dtype.size(),
        bytes.len()
    );
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    write_header(&mut w, dtype, shape)?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

fn as_bytes_f32(data: &[f32]) -> &[u8] {
    // f32 -> bytes on a little-endian target is a plain reinterpret; all
    // supported platforms here are LE.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn as_bytes_i32(data: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn as_bytes_i64(data: &[i64]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 8) }
}

/// Write a 1-D f32 array.
pub fn write_f32_1d(path: &Path, data: &[f32]) -> Result<()> {
    write_array(path, Dtype::F32, &[data.len()], as_bytes_f32(data))
}

/// Write a 2-D f32 array (C order, `rows * cols == data.len()`).
pub fn write_f32_2d(path: &Path, data: &[f32], rows: usize, cols: usize) -> Result<()> {
    write_array(path, Dtype::F32, &[rows, cols], as_bytes_f32(data))
}

/// Write a 1-D i32 array.
pub fn write_i32_1d(path: &Path, data: &[i32]) -> Result<()> {
    write_array(path, Dtype::I32, &[data.len()], as_bytes_i32(data))
}

/// Write a 1-D i64 array.
pub fn write_i64_1d(path: &Path, data: &[i64]) -> Result<()> {
    write_array(path, Dtype::I64, &[data.len()], as_bytes_i64(data))
}

/// Incremental `.npy` writer: reserve the (fixed-size) header, append
/// rows chunk by chunk, then [`NpyWriter::finalize`] back-patches the
/// true shape and fsyncs. The output is byte-identical to the one-shot
/// `write_*` functions for the same data, but peak memory is whatever
/// the caller buffers per append — the array itself never has to exist
/// in RAM. Until finalize runs, the file carries a valid zero-row
/// header, so a crashed run leaves a loadable (empty) array rather than
/// a torn one.
pub struct NpyWriter {
    file: BufWriter<std::fs::File>,
    path: PathBuf,
    dtype: Dtype,
    /// `None` = 1-D; `Some(c)` = 2-D with `c` columns per row.
    cols: Option<usize>,
    /// Elements appended so far (validated as whole rows on finalize).
    elems: usize,
}

impl NpyWriter {
    /// Create (truncate) `path` and reserve the header block.
    pub fn create(path: &Path, dtype: Dtype, cols: Option<usize>) -> Result<NpyWriter> {
        if let Some(c) = cols {
            ensure!(c > 0, "zero-column npy shape");
        }
        let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
        let mut file = BufWriter::new(f);
        file.write_all(&Self::header_bytes(dtype, 0, cols)?)?;
        // Push the placeholder header to disk now so a crash mid-append
        // leaves a loadable empty array, not a 0-byte file.
        file.flush()?;
        Ok(NpyWriter {
            file,
            path: path.to_path_buf(),
            dtype,
            cols,
            elems: 0,
        })
    }

    fn shape(rows: usize, cols: Option<usize>) -> Vec<usize> {
        match cols {
            None => vec![rows],
            Some(c) => vec![rows, c],
        }
    }

    fn header_bytes(dtype: Dtype, rows: usize, cols: Option<usize>) -> Result<Vec<u8>> {
        let mut header = Vec::with_capacity(HEADER_BLOCK);
        write_header(&mut header, dtype, &Self::shape(rows, cols))?;
        ensure!(
            header.len() == HEADER_BLOCK,
            "npy header for {rows} rows is {} bytes, not the reserved {HEADER_BLOCK}",
            header.len()
        );
        Ok(header)
    }

    /// Whole rows appended so far (partial trailing rows excluded).
    pub fn rows(&self) -> usize {
        self.elems / self.cols.unwrap_or(1)
    }

    /// Append elements already in raw little-endian form (the streaming
    /// shard-merge path). Must be a whole number of elements; row
    /// boundaries may fall mid-append and are validated at finalize.
    pub fn append_raw(&mut self, bytes: &[u8]) -> Result<()> {
        ensure!(
            bytes.len() % self.dtype.size() == 0,
            "raw append of {} bytes is not whole {}-byte elements",
            bytes.len(),
            self.dtype.size()
        );
        self.file.write_all(bytes)?;
        self.elems += bytes.len() / self.dtype.size();
        Ok(())
    }

    /// Append f32 elements (row-major for 2-D arrays).
    pub fn append_f32(&mut self, data: &[f32]) -> Result<()> {
        ensure!(self.dtype == Dtype::F32, "appending f32 to {:?}", self.dtype);
        self.append_raw(as_bytes_f32(data))
    }

    /// Append i32 elements.
    pub fn append_i32(&mut self, data: &[i32]) -> Result<()> {
        ensure!(self.dtype == Dtype::I32, "appending i32 to {:?}", self.dtype);
        self.append_raw(as_bytes_i32(data))
    }

    /// Append i64 elements.
    pub fn append_i64(&mut self, data: &[i64]) -> Result<()> {
        ensure!(self.dtype == Dtype::I64, "appending i64 to {:?}", self.dtype);
        self.append_raw(as_bytes_i64(data))
    }

    /// Patch the true shape into the reserved header block, flush and
    /// fsync. Returns the final row count.
    pub fn finalize(mut self) -> Result<usize> {
        let per_row = self.cols.unwrap_or(1);
        ensure!(
            self.elems % per_row == 0,
            "{} elements do not fill whole {per_row}-element rows",
            self.elems
        );
        let rows = self.elems / per_row;
        let header = Self::header_bytes(self.dtype, rows, self.cols)?;
        self.file.flush()?;
        let f = self.file.get_mut();
        f.seek(SeekFrom::Start(0))?;
        f.write_all(&header)?;
        f.sync_all().with_context(|| format!("fsync {:?}", self.path))?;
        Ok(rows)
    }
}

/// A loaded array (for round-trip tests and the Rust-side consumers).
#[derive(Debug, Clone, PartialEq)]
pub struct NpyArray {
    /// Element type.
    pub dtype: Dtype,
    /// Shape (1-D or 2-D).
    pub shape: Vec<usize>,
    /// Raw little-endian payload.
    pub data: Vec<u8>,
}

impl NpyArray {
    /// View as f32 slice.
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        ensure!(self.dtype == Dtype::F32, "not an f32 array");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// View as i32 slice.
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        ensure!(self.dtype == Dtype::I32, "not an i32 array");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Open a `.npy` file (v1.0/2.0, C-order, supported dtypes only) and
/// parse its header, returning a reader positioned at the first payload
/// byte. The streaming primitive behind [`read`] and the bounded-memory
/// shard merge in `datagen` — callers copy the payload through a fixed
/// buffer instead of loading it whole.
pub fn open_payload(path: &Path) -> Result<(Dtype, Vec<usize>, BufReader<std::fs::File>)> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic[..6] == b"\x93NUMPY", "not an npy file");
    let major = magic[6];
    let header_len = if major == 1 {
        let mut b = [0u8; 2];
        r.read_exact(&mut b)?;
        u16::from_le_bytes(b) as usize
    } else {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        u32::from_le_bytes(b) as usize
    };
    let mut header = vec![0u8; header_len];
    r.read_exact(&mut header)?;
    let header = String::from_utf8(header)?;

    // Tiny ad-hoc parse of the python dict literal.
    let descr = header
        .split("'descr':")
        .nth(1)
        .and_then(|s| s.split('\'').nth(1))
        .context("npy header missing descr")?;
    let dtype = Dtype::from_descr(descr)?;
    ensure!(
        header.contains("'fortran_order': False"),
        "fortran order unsupported"
    );
    let shape_str = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .context("npy header missing shape")?;
    let shape: Vec<usize> = shape_str
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().context("bad shape dim"))
        .collect::<Result<_>>()?;
    Ok((dtype, shape, r))
}

/// Read a `.npy` file fully into memory.
pub fn read(path: &Path) -> Result<NpyArray> {
    let (dtype, shape, mut r) = open_payload(path)?;
    let n: usize = shape.iter().product();
    let mut data = vec![0u8; n * dtype.size()];
    r.read_exact(&mut data)?;
    Ok(NpyArray { dtype, shape, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tao-npy-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn f32_2d_round_trip() {
        let path = tmp("a.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        write_f32_2d(&path, &data, 3, 4).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.shape, vec![3, 4]);
        assert_eq!(back.as_f32().unwrap(), data);
    }

    #[test]
    fn i32_1d_round_trip() {
        let path = tmp("b.npy");
        let data: Vec<i32> = vec![-1, 0, 7, i32::MAX];
        write_i32_1d(&path, &data).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.shape, vec![4]);
        assert_eq!(back.as_i32().unwrap(), data);
    }

    #[test]
    fn header_is_64_byte_aligned() {
        let path = tmp("c.npy");
        write_f32_1d(&path, &[1.0]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Payload starts at a multiple of 64.
        let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + header_len) % 64, 0);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let path = tmp("d.npy");
        assert!(write_f32_2d(&path, &[1.0, 2.0, 3.0], 2, 2).is_err());
    }

    #[test]
    fn wrong_dtype_view_rejected() {
        let path = tmp("e.npy");
        write_i32_1d(&path, &[1, 2]).unwrap();
        let back = read(&path).unwrap();
        assert!(back.as_f32().is_err());
    }

    #[test]
    fn empty_array_round_trips() {
        let path = tmp("f.npy");
        write_f32_1d(&path, &[]).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.shape, vec![0]);
        assert!(back.as_f32().unwrap().is_empty());
    }

    #[test]
    fn header_block_is_fixed_across_shapes() {
        // The NpyWriter back-patch relies on every header padding to the
        // same 128-byte block, including absurd row counts.
        for shape in [
            vec![0usize],
            vec![1],
            vec![usize::MAX / 2],
            vec![0, 1],
            vec![123_456_789, 154],
            vec![usize::MAX / 4, 999_999],
        ] {
            for dtype in [Dtype::F32, Dtype::I32, Dtype::I64] {
                let mut buf = Vec::new();
                write_header(&mut buf, dtype, &shape).unwrap();
                assert_eq!(buf.len(), HEADER_BLOCK, "shape {shape:?} {dtype:?}");
            }
        }
    }

    #[test]
    fn incremental_writer_matches_one_shot_2d() {
        let data: Vec<f32> = (0..35 * 7).map(|i| i as f32 * 0.25 - 3.0).collect();
        let one = tmp("w-one.npy");
        write_f32_2d(&one, &data, 35, 7).unwrap();
        let inc = tmp("w-inc.npy");
        let mut w = NpyWriter::create(&inc, Dtype::F32, Some(7)).unwrap();
        // Uneven chunks, including one that splits mid-row.
        w.append_f32(&data[..70]).unwrap();
        w.append_f32(&data[70..73]).unwrap();
        w.append_f32(&data[73..140]).unwrap();
        w.append_f32(&data[140..]).unwrap();
        assert_eq!(w.finalize().unwrap(), 35);
        assert_eq!(std::fs::read(&one).unwrap(), std::fs::read(&inc).unwrap());
    }

    #[test]
    fn incremental_writer_matches_one_shot_1d_i32() {
        let data: Vec<i32> = (0..1000).map(|i| i * 3 - 500).collect();
        let one = tmp("w1-one.npy");
        write_i32_1d(&one, &data).unwrap();
        let inc = tmp("w1-inc.npy");
        let mut w = NpyWriter::create(&inc, Dtype::I32, None).unwrap();
        for chunk in data.chunks(137) {
            w.append_i32(chunk).unwrap();
        }
        assert_eq!(w.finalize().unwrap(), 1000);
        assert_eq!(std::fs::read(&one).unwrap(), std::fs::read(&inc).unwrap());
    }

    #[test]
    fn incremental_writer_empty_matches_one_shot() {
        let one = tmp("we-one.npy");
        write_f32_1d(&one, &[]).unwrap();
        let inc = tmp("we-inc.npy");
        let w = NpyWriter::create(&inc, Dtype::F32, None).unwrap();
        assert_eq!(w.finalize().unwrap(), 0);
        assert_eq!(std::fs::read(&one).unwrap(), std::fs::read(&inc).unwrap());
    }

    #[test]
    fn incremental_writer_rejects_partial_rows_and_wrong_dtype() {
        let path = tmp("wbad.npy");
        let mut w = NpyWriter::create(&path, Dtype::F32, Some(4)).unwrap();
        assert!(w.append_i32(&[1, 2, 3, 4]).is_err());
        w.append_f32(&[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(w.rows(), 0);
        // 3 elements do not fill a 4-column row.
        assert!(w.finalize().is_err());

        let mut w = NpyWriter::create(&path, Dtype::I32, None).unwrap();
        // Raw appends must be whole elements.
        assert!(w.append_raw(&[0u8; 6]).is_err());
        w.append_raw(&[0u8; 8]).unwrap();
        assert_eq!(w.finalize().unwrap(), 2);
    }

    #[test]
    fn open_payload_positions_at_first_byte() {
        let path = tmp("op.npy");
        let data: Vec<i32> = vec![11, 22, 33];
        write_i32_1d(&path, &data).unwrap();
        let (dtype, shape, mut r) = open_payload(&path).unwrap();
        assert_eq!(dtype, Dtype::I32);
        assert_eq!(shape, vec![3]);
        let mut first = [0u8; 4];
        r.read_exact(&mut first).unwrap();
        assert_eq!(i32::from_le_bytes(first), 11);
    }
}
