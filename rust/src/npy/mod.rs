//! Minimal NumPy `.npy` (format v1.0) writer/reader.
//!
//! The interchange between `tao datagen` (Rust) and the build-time
//! training stack (Python) is plain `.npy` arrays — features, opcode ids
//! and labels — so the Python side is just `np.load`. Supports the three
//! dtypes the pipeline needs: `f32`, `i32`, `i64`, in 1-D and 2-D
//! C-contiguous layouts.

use anyhow::{bail, ensure, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8] = b"\x93NUMPY\x01\x00";

/// Supported element types.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    /// little-endian f32 (`<f4`)
    F32,
    /// little-endian i32 (`<i4`)
    I32,
    /// little-endian i64 (`<i8`)
    I64,
}

impl Dtype {
    fn descr(self) -> &'static str {
        match self {
            Dtype::F32 => "<f4",
            Dtype::I32 => "<i4",
            Dtype::I64 => "<i8",
        }
    }

    fn size(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::I64 => 8,
        }
    }

    fn from_descr(s: &str) -> Result<Dtype> {
        match s {
            "<f4" => Ok(Dtype::F32),
            "<i4" => Ok(Dtype::I32),
            "<i8" => Ok(Dtype::I64),
            _ => bail!("unsupported npy dtype {s:?}"),
        }
    }
}

fn write_header(w: &mut impl Write, dtype: Dtype, shape: &[usize]) -> Result<()> {
    let shape_str = match shape.len() {
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '{}', 'fortran_order': False, 'shape': {}, }}",
        dtype.descr(),
        shape_str
    );
    // Pad so that magic(8) + len(2) + header is a multiple of 64.
    let unpadded = MAGIC.len() + 2 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    w.write_all(MAGIC)?;
    w.write_all(&(header.len() as u16).to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    Ok(())
}

fn write_array(path: &Path, dtype: Dtype, shape: &[usize], bytes: &[u8]) -> Result<()> {
    let n: usize = shape.iter().product();
    ensure!(
        bytes.len() == n * dtype.size(),
        "shape {:?} needs {} bytes, got {}",
        shape,
        n * dtype.size(),
        bytes.len()
    );
    let f = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(f);
    write_header(&mut w, dtype, shape)?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

fn as_bytes_f32(data: &[f32]) -> &[u8] {
    // f32 -> bytes on a little-endian target is a plain reinterpret; all
    // supported platforms here are LE.
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn as_bytes_i32(data: &[i32]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) }
}

fn as_bytes_i64(data: &[i64]) -> &[u8] {
    unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 8) }
}

/// Write a 1-D f32 array.
pub fn write_f32_1d(path: &Path, data: &[f32]) -> Result<()> {
    write_array(path, Dtype::F32, &[data.len()], as_bytes_f32(data))
}

/// Write a 2-D f32 array (C order, `rows * cols == data.len()`).
pub fn write_f32_2d(path: &Path, data: &[f32], rows: usize, cols: usize) -> Result<()> {
    write_array(path, Dtype::F32, &[rows, cols], as_bytes_f32(data))
}

/// Write a 1-D i32 array.
pub fn write_i32_1d(path: &Path, data: &[i32]) -> Result<()> {
    write_array(path, Dtype::I32, &[data.len()], as_bytes_i32(data))
}

/// Write a 1-D i64 array.
pub fn write_i64_1d(path: &Path, data: &[i64]) -> Result<()> {
    write_array(path, Dtype::I64, &[data.len()], as_bytes_i64(data))
}

/// A loaded array (for round-trip tests and the Rust-side consumers).
#[derive(Debug, Clone, PartialEq)]
pub struct NpyArray {
    /// Element type.
    pub dtype: Dtype,
    /// Shape (1-D or 2-D).
    pub shape: Vec<usize>,
    /// Raw little-endian payload.
    pub data: Vec<u8>,
}

impl NpyArray {
    /// View as f32 slice.
    pub fn as_f32(&self) -> Result<Vec<f32>> {
        ensure!(self.dtype == Dtype::F32, "not an f32 array");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// View as i32 slice.
    pub fn as_i32(&self) -> Result<Vec<i32>> {
        ensure!(self.dtype == Dtype::I32, "not an i32 array");
        Ok(self
            .data
            .chunks_exact(4)
            .map(|b| i32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }
}

/// Read a `.npy` file (v1.0/2.0, C-order, supported dtypes only).
pub fn read(path: &Path) -> Result<NpyArray> {
    let f = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let mut r = BufReader::new(f);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    ensure!(&magic[..6] == b"\x93NUMPY", "not an npy file");
    let major = magic[6];
    let header_len = if major == 1 {
        let mut b = [0u8; 2];
        r.read_exact(&mut b)?;
        u16::from_le_bytes(b) as usize
    } else {
        let mut b = [0u8; 4];
        r.read_exact(&mut b)?;
        u32::from_le_bytes(b) as usize
    };
    let mut header = vec![0u8; header_len];
    r.read_exact(&mut header)?;
    let header = String::from_utf8(header)?;

    // Tiny ad-hoc parse of the python dict literal.
    let descr = header
        .split("'descr':")
        .nth(1)
        .and_then(|s| s.split('\'').nth(1))
        .context("npy header missing descr")?;
    let dtype = Dtype::from_descr(descr)?;
    ensure!(
        header.contains("'fortran_order': False"),
        "fortran order unsupported"
    );
    let shape_str = header
        .split("'shape':")
        .nth(1)
        .and_then(|s| s.split('(').nth(1))
        .and_then(|s| s.split(')').next())
        .context("npy header missing shape")?;
    let shape: Vec<usize> = shape_str
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().context("bad shape dim"))
        .collect::<Result<_>>()?;
    let n: usize = shape.iter().product();
    let mut data = vec![0u8; n * dtype.size()];
    r.read_exact(&mut data)?;
    Ok(NpyArray { dtype, shape, data })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("tao-npy-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn f32_2d_round_trip() {
        let path = tmp("a.npy");
        let data: Vec<f32> = (0..12).map(|i| i as f32 * 0.5).collect();
        write_f32_2d(&path, &data, 3, 4).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.shape, vec![3, 4]);
        assert_eq!(back.as_f32().unwrap(), data);
    }

    #[test]
    fn i32_1d_round_trip() {
        let path = tmp("b.npy");
        let data: Vec<i32> = vec![-1, 0, 7, i32::MAX];
        write_i32_1d(&path, &data).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.shape, vec![4]);
        assert_eq!(back.as_i32().unwrap(), data);
    }

    #[test]
    fn header_is_64_byte_aligned() {
        let path = tmp("c.npy");
        write_f32_1d(&path, &[1.0]).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Payload starts at a multiple of 64.
        let header_len = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
        assert_eq!((10 + header_len) % 64, 0);
    }

    #[test]
    fn shape_mismatch_rejected() {
        let path = tmp("d.npy");
        assert!(write_f32_2d(&path, &[1.0, 2.0, 3.0], 2, 2).is_err());
    }

    #[test]
    fn wrong_dtype_view_rejected() {
        let path = tmp("e.npy");
        write_i32_1d(&path, &[1, 2]).unwrap();
        let back = read(&path).unwrap();
        assert!(back.as_f32().is_err());
    }

    #[test]
    fn empty_array_round_trips() {
        let path = tmp("f.npy");
        write_f32_1d(&path, &[]).unwrap();
        let back = read(&path).unwrap();
        assert_eq!(back.shape, vec![0]);
        assert!(back.as_f32().unwrap().is_empty());
    }
}
