//! Paper table/figure regeneration harness (`tao report <artifact>`).
//!
//! Each report prints the same rows/series as the paper's artifact and
//! writes a copy under `reports/`. Reports that need *trained models*
//! consume the AOT artifacts (`artifacts/tao_*.hlo.txt`); reports that
//! additionally need *retraining sweeps* (Figures 12-14, Table 5 and the
//! Tao side of Figure 15) live in `python/compile/experiments.py` (build
//! time) and are joined here from their cached outputs.
//!
//! | paper artifact | subcommand          | implemented in |
//! |----------------|---------------------|----------------|
//! | Table 1        | `report table1`     | here           |
//! | Figure 2       | `report figure2`    | here           |
//! | Figure 9       | `report figure9`    | here (+ artifacts) |
//! | Figure 10a/b   | `report figure10a/b`| here           |
//! | Figure 11      | `report figure11`   | here (+ artifacts) |
//! | Table 4        | `report table4`     | here (+ artifacts) |
//! | Table 6        | `report table6`     | here (+ manifest)  |
//! | Figure 15 (gem5 side) | `report figure15` | here (+ cached Tao side) |
//! | Figures 12-14, Table 5 | `python -m compile.experiments <name>` | python |

pub mod model_reports;
pub mod sim_reports;

use crate::cli::args::Args;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Dispatch `tao report <name>`.
pub fn cmd_report(mut args: Args) -> Result<()> {
    let name = args
        .next_positional()
        .context(
            "usage: tao report <table1|figure2|figure9|figure10a|figure10b|figure11|table4|\
             table6|figure15>",
        )?;
    match name.as_str() {
        "table1" => sim_reports::table1(args),
        "figure2" => sim_reports::figure2(args),
        "figure10a" => sim_reports::figure10a(args),
        "figure10b" => sim_reports::figure10b(args),
        "table6" => sim_reports::table6(args),
        "figure15" => sim_reports::figure15(args),
        "figure9" => model_reports::figure9(args),
        "figure11" => model_reports::figure11(args),
        "table4" => model_reports::table4(args),
        other => bail!(
            "unknown report {other:?} (figures 12-14 + table5 are python-side: \
             `cd python && python -m compile.experiments {other}`)"
        ),
    }
}

/// Dispatch `tao dse`.
pub fn cmd_dse(args: Args) -> Result<()> {
    sim_reports::dse(args)
}

/// A tiny report sink: mirrors everything to stdout and `reports/<name>.txt`.
pub struct Report {
    file: std::fs::File,
}

impl Report {
    /// Create `reports/<name>.txt`.
    pub fn new(name: &str) -> Result<Report> {
        let dir = PathBuf::from("reports");
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("{name}.txt"));
        Ok(Report {
            file: std::fs::File::create(&path).with_context(|| format!("create {path:?}"))?,
        })
    }

    /// Emit one line.
    pub fn line(&mut self, s: impl AsRef<str>) {
        let s = s.as_ref();
        println!("{s}");
        let _ = writeln!(self.file, "{s}");
    }
}

/// Default artifact path for a µarch.
pub fn artifact_path(dir: &Path, model: &str, uarch: &str) -> PathBuf {
    dir.join(format!("{model}_uarch_{uarch}.hlo.txt"))
}
