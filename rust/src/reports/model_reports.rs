//! Model-dependent reports: Figure 9 (accuracy vs SimNet), Figure 11
//! (phase behaviour), Table 4 (end-to-end time decomposition). These
//! consume the AOT artifacts under `artifacts/`.

use super::{artifact_path, Report};
use crate::cli::args::Args;
use crate::coordinator::engine;
use crate::detailed::DetailedSim;
use crate::functional::FunctionalSim;
use crate::runtime::Session;
use crate::stats::{mean, simulation_error_percent};
use crate::uarch::UarchConfig;
use crate::util::{timer, Stopwatch};
use crate::workloads;
use anyhow::{Context, Result};
use std::path::PathBuf;

fn artifacts_dir(args: &mut Args) -> Result<PathBuf> {
    Ok(args
        .opt_value("--artifacts")?
        .unwrap_or_else(|| "artifacts".into())
        .into())
}

// SimNet's µarch-specific context input now comes from the shared
// `dataset::simnet_ctx_metrics` (the serving layer needs it too).
use crate::dataset::simnet_ctx_metrics;

/// Figure 9: CPI simulation error for {µArch A,B,C} × test benchmarks,
/// Tao vs SimNet.
pub fn figure9(mut args: Args) -> Result<()> {
    let dir = artifacts_dir(&mut args)?;
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(50_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    let workers: usize = args.opt_parse("--workers")?.unwrap_or(1);
    args.finish()?;
    let mut rep = Report::new("figure9")?;
    rep.line("Figure 9 — CPI simulation error vs ground truth, Tao vs SimNet");
    rep.line(format!(
        "{:<8} {:<6} | {:>10} | {:>9} | {:>9}",
        "uarch", "bench", "truth CPI", "Tao err", "SimNet err"
    ));
    let mut tao_errs = Vec::new();
    let mut simnet_errs = Vec::new();
    for uarch in ["a", "b", "c"] {
        let cfg = UarchConfig::preset(uarch).unwrap();
        let tao_model = artifact_path(&dir, "tao", uarch);
        let simnet_model = artifact_path(&dir, "simnet", uarch);
        for w in workloads::testing() {
            let program = w.build(seed);
            let functional = FunctionalSim::new(&program).run(insts);
            let (_, truth) = DetailedSim::new(&program, &cfg).stats_only().run(insts);

            let tao = engine::simulate_parallel(&tao_model, &functional.records, workers, None)
                .with_context(|| format!("tao on {uarch}/{}", w.name))?;
            let tao_err = simulation_error_percent(tao.metrics.cpi(), truth.cpi());
            tao_errs.push(tao_err);

            let simnet_err = if simnet_model.exists() {
                let ctx = simnet_ctx_metrics(&program, &cfg, insts);
                let r = engine::simulate_parallel(
                    &simnet_model,
                    &functional.records,
                    workers,
                    Some(&ctx),
                )?;
                let e = simulation_error_percent(r.metrics.cpi(), truth.cpi());
                simnet_errs.push(e);
                format!("{e:>8.2}%")
            } else {
                "   (n/a)".into()
            };
            rep.line(format!(
                "{:<8} {:<6} | {:>10.3} | {:>8.2}% | {}",
                cfg.name,
                w.name,
                truth.cpi(),
                tao_err,
                simnet_err
            ));
        }
    }
    rep.line(format!(
        "average: Tao {:.2}%{} (paper: SimNet 5.11%, Tao 5.23% — parity is the claim)",
        mean(&tao_errs),
        if simnet_errs.is_empty() {
            String::new()
        } else {
            format!(", SimNet {:.2}%", mean(&simnet_errs))
        }
    ));
    Ok(())
}

/// Figure 11: phase-level CPI / L1D MPKI / branch MPKI series vs ground
/// truth on µArch A.
pub fn figure11(mut args: Args) -> Result<()> {
    let dir = artifacts_dir(&mut args)?;
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(50_000);
    let window: u64 = args.opt_parse("--window")?.unwrap_or(5_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    args.finish()?;
    let mut rep = Report::new("figure11")?;
    rep.line(format!(
        "Figure 11 — phase behaviour on uarch_a ({insts} insts, window {window})"
    ));
    let cfg = UarchConfig::uarch_a();
    let model = artifact_path(&dir, "tao", "a");
    let mut session = Session::load(&model)?;
    for w in workloads::testing() {
        let program = w.build(seed);
        let functional = FunctionalSim::new(&program).run(insts);
        let result =
            engine::simulate_records(&mut session, &functional.records, None, Some(window))?;
        // Ground truth per window from the detailed trace.
        let (det, _) = DetailedSim::new(&program, &cfg).run(insts);
        let adj = crate::dataset::adjust(&det);
        let mut truth = crate::stats::PhaseSeries::new(window);
        for s in &adj.samples {
            truth.push(
                s.labels.fetch_latency as f64,
                s.labels.branch_mispred,
                s.labels.access_level.is_l1_miss(),
                s.labels.icache_miss,
                s.labels.tlb_miss,
            );
        }
        truth.finish();
        rep.line(format!("--- {} ---", w.name));
        rep.line(format!(
            "{:>4} | {:>9} {:>9} | {:>9} {:>9} | {:>9} {:>9}",
            "win", "CPI true", "CPI pred", "L1D true", "L1D pred", "bMPKI tr", "bMPKI pr"
        ));
        let pred = result.phase.as_ref().context("phase series missing")?;
        for (i, (t, p)) in truth.windows.iter().zip(&pred.windows).enumerate() {
            rep.line(format!(
                "{:>4} | {:>9.3} {:>9.3} | {:>9.2} {:>9.2} | {:>9.2} {:>9.2}",
                i,
                t.cpi(),
                p.cpi(),
                t.l1d_mpki(),
                p.l1d_mpki(),
                t.branch_mpki(),
                p.branch_mpki()
            ));
        }
    }
    Ok(())
}

/// Table 4: end-to-end time decomposition — Tao vs SimNet vs detailed
/// simulation, scaled to `--insts`.
pub fn table4(mut args: Args) -> Result<()> {
    let dir = artifacts_dir(&mut args)?;
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(100_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    let workers: usize = args.opt_parse("--workers")?.unwrap_or(1);
    args.finish()?;
    let mut rep = Report::new("table4")?;
    rep.line(format!(
        "Table 4 — end-to-end simulation time for {insts} instructions (test benchmarks, uarch_a)"
    ));
    let cfg = UarchConfig::uarch_a();
    let tao_model = artifact_path(&dir, "tao", "a");
    let simnet_model = artifact_path(&dir, "simnet", "a");

    // Training times from the manifest.
    let manifest = std::fs::read_to_string(dir.join("manifest.json"))
        .ok()
        .and_then(|t| crate::util::json::Json::parse(&t).ok());
    let train_time = |key: &str| -> Option<f64> {
        manifest
            .as_ref()?
            .get("models")?
            .get(key)?
            .get("train_seconds")?
            .as_f64()
    };

    let mut t_func = Stopwatch::new();
    let mut t_det = Stopwatch::new();
    let mut tao_infer = 0.0;
    let mut simnet_infer = 0.0;
    let mut total = 0u64;
    for w in workloads::testing() {
        let program = w.build(seed);
        let functional = t_func.time(|| FunctionalSim::new(&program).run(insts));
        // SimNet's input requires the detailed trace of the target µarch.
        let ctx = t_det.time(|| simnet_ctx_metrics(&program, &cfg, insts));
        total += functional.records.len() as u64;

        let tao = engine::simulate_parallel(&tao_model, &functional.records, workers, None)?;
        tao_infer += tao.elapsed.as_secs_f64();
        if simnet_model.exists() {
            let r =
                engine::simulate_parallel(&simnet_model, &functional.records, workers, Some(&ctx))?;
            simnet_infer += r.elapsed.as_secs_f64();
        }
    }
    let func_s = t_func.elapsed().as_secs_f64();
    let det_s = t_det.elapsed().as_secs_f64();
    rep.line(format!("{:<42} {:>10}", "component", "seconds"));
    if let Some(t) = train_time("tao_uarch_a") {
        rep.line(format!("{:<42} {:>10.1}", "Tao training (transfer, from manifest)", t));
    }
    if let Some(t) = train_time("simnet_uarch_a") {
        rep.line(format!("{:<42} {:>10.1}", "SimNet training (from manifest)", t));
    }
    rep.line(format!(
        "{:<42} {:>10.2}",
        "Tao trace generation (functional)", func_s
    ));
    rep.line(format!(
        "{:<42} {:>10.2}",
        "SimNet trace generation (detailed, per-uarch)", det_s
    ));
    rep.line(format!("{:<42} {:>10.2}", "Tao inference", tao_infer));
    if simnet_model.exists() {
        rep.line(format!("{:<42} {:>10.2}", "SimNet inference", simnet_infer));
    }
    rep.line(format!(
        "{:<42} {:>10.2}",
        "detailed simulation (gem5-equivalent, total)", det_s
    ));
    let tao_total = func_s + tao_infer;
    let simnet_total = det_s + simnet_infer;
    rep.line(format!(
        "tracegen speedup (functional vs detailed): {:.1}x  (paper: 24.94x)",
        det_s / func_s
    ));
    if simnet_model.exists() {
        rep.line(format!(
            "simulation speedup (Tao vs SimNet, excl. training): {:.2}x  (paper: 7.81x)",
            simnet_total / tao_total
        ));
    }
    rep.line(format!(
        "throughput: functional tracegen {:.2} MIPS, Tao end-to-end {:.3} MIPS",
        timer::mips(total, t_func.elapsed()),
        total as f64 / tao_total / 1e6,
    ));
    rep.line(
        "(absolute seconds differ from the paper's A100 testbed; the decomposition shape is the claim)",
    );
    Ok(())
}
