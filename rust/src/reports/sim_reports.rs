//! Simulator-side reports: Table 1, Figure 2, Figure 10a/b, Table 6,
//! Figure 15 (ground-truth series) and the `tao dse` characterization.

use super::Report;
use crate::cli::args::Args;
use crate::dataset;
use crate::detailed::DetailedSim;
use crate::dse::{self, DesignSpace, PerfVector, SelectionStrategy};
use crate::functional::FunctionalSim;
use crate::trace::DetailedRecord;
use crate::uarch::{CacheGeometry, PredictorKind, UarchConfig};
use crate::util::{timer, Rng, Stopwatch};
use crate::workloads;
use anyhow::Result;

fn presets() -> Vec<UarchConfig> {
    vec![
        UarchConfig::uarch_a(),
        UarchConfig::uarch_b(),
        UarchConfig::uarch_c(),
    ]
}

/// Table 1: instruction counts, detailed vs functional trace (dee).
pub fn table1(mut args: Args) -> Result<()> {
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(100_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    args.finish()?;
    let mut rep = Report::new("table1")?;
    rep.line("Table 1 — # instructions, detailed vs functional trace (531.deepsjeng_r stand-in)");
    rep.line(format!(
        "{:>10} | {:>16} | {:>16} | {:>7}",
        "budget", "detailed (O3)", "functional", "diff%"
    ));
    let w = workloads::by_name("dee").unwrap();
    let program = w.build(seed);
    for budget in [insts, insts * 10] {
        let func = FunctionalSim::new(&program).run(budget);
        let (det, _) = DetailedSim::new(&program, &UarchConfig::uarch_a()).run(budget);
        let c = dataset::trace_counts(&det, &func);
        rep.line(format!(
            "{:>10} | {:>16} | {:>16} | {:>6.2}%",
            budget,
            c.detailed,
            c.functional,
            c.diff_percent()
        ));
    }
    rep.line(
        "(paper: 1M → 2,655,925 vs 2,528,617 = 5.2%; shape check: detailed > functional by a few %)",
    );
    Ok(())
}

/// Figure 2: the §4.1 adjustment walked through on a real trace snippet.
pub fn figure2(mut args: Args) -> Result<()> {
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    args.finish()?;
    let mut rep = Report::new("figure2")?;
    let w = workloads::by_name("dee").unwrap();
    let program = w.build(seed);
    let (det, _) = DetailedSim::new(&program, &UarchConfig::uarch_a()).run(3_000);
    let adj = dataset::adjust(&det);

    // Find the first mispredicted branch with squashed records after it.
    let mut idx_mispred = None;
    for (i, r) in det.records.iter().enumerate() {
        if let DetailedRecord::Retired(info) = r {
            if info.branch_mispred
                && matches!(det.records.get(i + 1), Some(DetailedRecord::Squashed { .. }))
            {
                idx_mispred = Some(i);
                break;
            }
        }
    }
    rep.line("Figure 2 — training-dataset construction on a detailed-trace snippet");
    rep.line("detailed trace (fetch-ordered records):");
    if let Some(i) = idx_mispred {
        for r in det.records.iter().skip(i.saturating_sub(1)).take(8) {
            match r {
                DetailedRecord::Retired(info) => rep.line(format!(
                    "  {:>8x} {:<6} fetch@{:<6} retire@{:<6}{}",
                    info.func.pc,
                    info.func.opcode.mnemonic(),
                    info.fetch_clock,
                    info.retire_clock,
                    if info.branch_mispred { "  [mispredicted]" } else { "" }
                )),
                DetailedRecord::Squashed { pc, opcode, fetch_clock } => rep.line(format!(
                    "  {:>8x} {:<6} fetch@{:<6} [squashed speculative]",
                    pc,
                    opcode.mnemonic(),
                    fetch_clock
                )),
                DetailedRecord::NopStall { fetch_clock } => rep.line(format!(
                    "  {:>8} nop    fetch@{:<6} [pipeline stall]",
                    "-",
                    fetch_clock
                )),
            }
        }
    }
    rep.line("adjusted trace: squashed/nop records removed; their time re-attributed");
    rep.line("to the next retired instruction's fetch latency.");
    rep.line(format!(
        "invariant: total cycles preserved — detailed {} == reconstructed {}",
        det.total_cycles,
        adj.reconstructed_cycles()
    ));
    anyhow::ensure!(det.total_cycles == adj.reconstructed_cycles(), "Figure 2 invariant violated");
    Ok(())
}

/// Figure 10a: speculative vs nop instruction share of the extra records.
pub fn figure10a(mut args: Args) -> Result<()> {
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(50_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    args.finish()?;
    let mut rep = Report::new("figure10a")?;
    rep.line("Figure 10a — instruction differences (% of committed) in detailed traces");
    rep.line(format!(
        "{:<10} {:<6} | {:>10} | {:>8} | {:>9} | {:>9}",
        "uarch", "bench", "committed", "spec%", "nop%", "spec:nop"
    ));
    for cfg in presets() {
        for w in workloads::suite() {
            let program = w.build(seed);
            let (det, stats) = DetailedSim::new(&program, &cfg).run(insts);
            let spec = 100.0 * stats.squashed as f64 / stats.instructions as f64;
            let nop = 100.0 * stats.nops as f64 / stats.instructions as f64;
            let ratio = if stats.nops > 0 {
                stats.squashed as f64 / stats.nops as f64
            } else {
                f64::INFINITY
            };
            rep.line(format!(
                "{:<10} {:<6} | {:>10} | {:>7.2}% | {:>8.2}% | {:>9.1}",
                cfg.name,
                w.name,
                det.retired_count(),
                spec,
                nop,
                ratio
            ));
        }
    }
    rep.line("(paper: extras are ~97% squashed speculative vs ~3% nop on average)");
    Ok(())
}

/// Figure 10b: trace-generation throughput, detailed vs functional.
pub fn figure10b(mut args: Args) -> Result<()> {
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(200_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    args.finish()?;
    let mut rep = Report::new("figure10b")?;
    rep.line("Figure 10b — trace generation throughput (MIPS)");
    rep.line(format!(
        "{:<10} {:<6} | {:>12} | {:>12} | {:>8}",
        "uarch", "bench", "detailed", "functional", "speedup"
    ));
    let mut det_tp = Vec::new();
    let mut fun_tp = Vec::new();
    for cfg in presets() {
        for w in workloads::suite() {
            let program = w.build(seed);
            let mut sw = Stopwatch::new();
            sw.time(|| {
                DetailedSim::new(&program, &cfg).run(insts);
            });
            let t_det = sw.elapsed();
            let mut sw2 = Stopwatch::new();
            sw2.time(|| {
                FunctionalSim::new(&program).run(insts);
            });
            let t_fun = sw2.elapsed();
            let d = timer::mips(insts, t_det);
            let f = timer::mips(insts, t_fun);
            det_tp.push(d);
            fun_tp.push(f);
            rep.line(format!(
                "{:<10} {:<6} | {:>9.2} MIPS | {:>9.2} MIPS | {:>7.1}x",
                cfg.name,
                w.name,
                d,
                f,
                f / d
            ));
        }
    }
    let avg_d = crate::stats::mean(&det_tp);
    let avg_f = crate::stats::mean(&fun_tp);
    rep.line(format!(
        "average: detailed {avg_d:.2} MIPS, functional {avg_f:.2} MIPS — {:.1}x (paper: 0.21 vs 5.29 = 25.2x)",
        avg_f / avg_d
    ));
    Ok(())
}

/// Characterize a sampled design with the four §4.3 metrics, averaged
/// over the training benchmarks.
pub fn characterize(cfg: &UarchConfig, insts: u64, seed: u64) -> PerfVector {
    let mut acc = PerfVector::default();
    let wls = workloads::training();
    for w in &wls {
        let program = w.build(seed);
        let (_, s) = DetailedSim::new(&program, cfg).stats_only().run(insts);
        acc.cpi += s.cpi();
        acc.l1_miss_rate += if s.mem_ops > 0 {
            s.l1d_misses as f64 / s.mem_ops as f64
        } else {
            0.0
        };
        acc.l2_miss_rate += if s.l1d_misses > 0 {
            s.l2d_misses as f64 / s.l1d_misses as f64
        } else {
            0.0
        };
        acc.mispredict_rate += s.mispredict_rate();
    }
    let n = wls.len() as f64;
    PerfVector {
        cpi: acc.cpi / n,
        l1_miss_rate: acc.l1_miss_rate / n,
        l2_miss_rate: acc.l2_miss_rate / n,
        mispredict_rate: acc.mispredict_rate / n,
    }
}

/// `tao dse`: sample designs, characterize, print the Figure 8 distance
/// matrix and the selected training pair.
pub fn dse(mut args: Args) -> Result<()> {
    let designs: usize = args.opt_parse("--designs")?.unwrap_or(8);
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(10_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    args.finish()?;
    let mut rep = Report::new("dse")?;
    let space = DesignSpace::table3();
    rep.line(format!(
        "Design space: {} points (Table 3). Sampling {designs} designs, {insts} insts per benchmark.",
        space.count()
    ));
    let mut rng = Rng::new(seed);
    let cfgs = space.sample(designs, &mut rng);
    let mut perfs = Vec::new();
    rep.line(format!(
        "{:<12} | {:>7} | {:>8} | {:>8} | {:>8}",
        "design", "CPI", "L1miss", "L2miss", "mispred"
    ));
    for cfg in &cfgs {
        let p = characterize(cfg, insts, seed);
        rep.line(format!(
            "{:<12} | {:>7.3} | {:>7.1}% | {:>7.1}% | {:>7.1}%",
            cfg.name,
            p.cpi,
            p.l1_miss_rate * 100.0,
            p.l2_miss_rate * 100.0,
            p.mispredict_rate * 100.0
        ));
        perfs.push(p);
    }
    let matrix = dse::distance_matrix(&perfs, SelectionStrategy::Mahalanobis);
    rep.line("Mahalanobis distance matrix:");
    for (i, row) in matrix.iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|d| format!("{d:5.2}")).collect();
        rep.line(format!("  {:<12} {}", cfgs[i].name, cells.join(" ")));
    }
    let (i, j) = dse::select_pair(&perfs, SelectionStrategy::Mahalanobis, &mut rng);
    rep.line(format!(
        "selected training pair (max Mahalanobis distance): {} + {}",
        cfgs[i].name, cfgs[j].name
    ));
    Ok(())
}

/// Table 6: preprocessing overhead of embedding construction.
pub fn table6(mut args: Args) -> Result<()> {
    let designs: usize = args.opt_parse("--designs")?.unwrap_or(16);
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(10_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    args.finish()?;
    let mut rep = Report::new("table6")?;
    rep.line("Table 6 — overhead of microarchitecture-agnostic embedding construction");
    let space = DesignSpace::table3();
    let mut rng = Rng::new(seed);
    let cfgs = space.sample(designs, &mut rng);
    let mut sw = Stopwatch::new();
    let perfs: Vec<PerfVector> =
        sw.time(|| cfgs.iter().map(|c| characterize(c, insts, seed)).collect());
    let sim_time = sw.elapsed();
    let mut sw2 = Stopwatch::new();
    let (i, j) = sw2.time(|| dse::select_pair(&perfs, SelectionStrategy::Mahalanobis, &mut rng));
    let select_time = sw2.elapsed();
    rep.line(format!(
        "random design selection + simulation ({designs} designs x {} train benches x {insts} insts): {:.2}s",
        workloads::training().len(),
        sim_time.as_secs_f64()
    ));
    rep.line(format!(
        "Mahalanobis selection: {:.4}s (picked {} + {})",
        select_time.as_secs_f64(),
        cfgs[i].name,
        cfgs[j].name
    ));
    // Shared-embedding training time comes from the AOT manifest.
    match std::fs::read_to_string("artifacts/manifest.json") {
        Ok(text) => {
            if let Ok(j) = crate::util::json::Json::parse(&text) {
                if let Some(t) = j
                    .get("timings")
                    .and_then(|t| t.get("shared_s"))
                    .and_then(|v| v.as_f64())
                {
                    rep.line(format!(
                        "training shared embeddings (from artifacts/manifest.json): {t:.1}s"
                    ));
                }
            }
        }
        Err(_) => rep.line(
            "training shared embeddings: run `make artifacts` to populate manifest.json",
        ),
    }
    rep.line("(paper: 0.35h simulation + 0.1min selection + 71h embedding training)");
    Ok(())
}

/// Figure 15 ground-truth series: L1D-size sweep (cache MPKI) and branch
/// predictor sweep (branch MPKI), averaged over test benchmarks. The Tao
/// prediction series is joined from the python experiments cache when
/// present (`reports/figure15_tao.txt`).
pub fn figure15(mut args: Args) -> Result<()> {
    let insts: u64 = args.opt_parse("--insts")?.unwrap_or(50_000);
    let seed: u64 = args.opt_parse("--seed")?.unwrap_or(42);
    args.finish()?;
    let mut rep = Report::new("figure15")?;

    // Note: the sweep averages over the FULL suite — our synthetic test
    // benchmarks all have working sets far beyond 128KB (mcf 8MiB random,
    // cac 4MiB streaming), so their L1D MPKI is physically flat across
    // this range; the L1-scale reuse lives in dee/nab/lee (see
    // DESIGN.md §1 on workload substitution).
    rep.line("Figure 15a — L1 Dcache size sweep, avg L1D MPKI over the suite (ground truth)");
    let mut cfg = UarchConfig::uarch_b();
    for size_kb in [16u64, 32, 64, 128] {
        cfg.name = format!("l1d_{size_kb}kb");
        cfg.l1d = CacheGeometry {
            size_bytes: size_kb << 10,
            assoc: cfg.l1d.assoc,
        };
        let mut mpkis = Vec::new();
        for w in workloads::suite() {
            let program = w.build(seed);
            let (_, s) = DetailedSim::new(&program, &cfg).stats_only().run(insts);
            mpkis.push(s.l1d_mpki());
        }
        rep.line(format!("  {size_kb:>4} KB : {:>7.2} MPKI", crate::stats::mean(&mpkis)));
    }

    rep.line(
        "Figure 15b — branch predictor sweep, avg branch MPKI over test benchmarks (ground truth)",
    );
    // Fresh base config for the second sweep (the first mutated l1d);
    // constructing a preset is cheaper than cloning one per point.
    let mut cfg = UarchConfig::uarch_b();
    for bp in PredictorKind::ALL {
        cfg.name = format!("bp_{}", bp.name());
        cfg.predictor = bp;
        let mut mpkis = Vec::new();
        for w in workloads::testing() {
            let program = w.build(seed);
            let (_, s) = DetailedSim::new(&program, &cfg).stats_only().run(insts);
            mpkis.push(s.branch_mpki());
        }
        rep.line(format!("  {:<12}: {:>7.2} MPKI", bp.name(), crate::stats::mean(&mpkis)));
    }
    match std::fs::read_to_string("reports/figure15_tao.txt") {
        Ok(tao_side) => {
            rep.line("--- Tao predictions (python -m compile.experiments figure15) ---");
            for l in tao_side.lines() {
                rep.line(l);
            }
        }
        Err(_) => rep.line(
            "(Tao prediction series: run `cd python && python -m compile.experiments figure15`)",
        ),
    }
    rep.line("(paper shape: MPKI falls 16->128KB; Local worst, TAGE_SC_L best)");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn characterize_produces_nonzero_vector() {
        let p = characterize(&UarchConfig::uarch_a(), 2_000, 1);
        assert!(p.cpi > 0.5);
        assert!(p.l1_miss_rate >= 0.0 && p.l1_miss_rate <= 1.0);
        assert!(p.mispredict_rate >= 0.0 && p.mispredict_rate <= 1.0);
    }

    #[test]
    fn characterize_distinguishes_designs() {
        let a = characterize(&UarchConfig::uarch_a(), 3_000, 1);
        let c = characterize(&UarchConfig::uarch_c(), 3_000, 1);
        assert!(a.cpi > c.cpi, "A {} should be slower than C {}", a.cpi, c.cpi);
    }
}
