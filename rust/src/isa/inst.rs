//! Instruction and program representation.
//!
//! Instructions carry exactly the static property surface the paper's
//! feature engineering consumes (§4.2): opcode, source/destination
//! registers, PC address, and (dynamically, via the simulators) the data
//! access address. Branch targets are instruction indices; the PC of
//! instruction `i` is `TEXT_BASE + 4*i`, mirroring a fixed-width ISA.

use super::opcode::{Condition, Opcode};
use super::regs::Reg;
use std::fmt;

/// Base virtual address of the text segment (instruction PCs).
pub const TEXT_BASE: u64 = 0x0040_0000;
/// Base virtual address of the data segment (memory operand addresses).
pub const DATA_BASE: u64 = 0x1000_0000;
/// Instruction width in bytes (fixed-width ISA).
pub const INST_BYTES: u64 = 4;

/// Width of a memory access in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MemWidth {
    /// 1 byte.
    Byte,
    /// 4 bytes.
    Word,
    /// 8 bytes.
    Double,
}

impl MemWidth {
    /// Access size in bytes.
    pub fn bytes(self) -> u64 {
        match self {
            MemWidth::Byte => 1,
            MemWidth::Word => 4,
            MemWidth::Double => 8,
        }
    }
}

/// A source operand: either a register or an immediate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Operand {
    /// Register operand.
    Reg(Reg),
    /// Immediate operand.
    Imm(i64),
}

/// A single TaoISA instruction.
///
/// Operand conventions (enforced by [`Instruction::validate`]):
/// * ALU: `dst = op(src1, src2|imm)`; `Madd`/`Fmadd` also read `src3`.
/// * Loads: `dst = mem[r(src1) + imm (+ r(src2))]`.
/// * Stores: `mem[r(src1) + imm (+ r(src2))] = r(src3)`.
/// * `Bcond`: branch to `target` if `cond(r(src1), r(src2))`.
/// * `Cbz`/`Cbnz`: branch to `target` on `r(src1) == 0` / `!= 0`.
/// * `B`/`Bl`: unconditional; `Bl` writes the return index to `x30`.
/// * `Ret`: jump to index stored in `r(src1)` (conventionally `x30`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Instruction {
    /// Operation.
    pub opcode: Opcode,
    /// Destination register, if any.
    pub dst: Option<Reg>,
    /// First source register (base register for memory ops).
    pub src1: Option<Reg>,
    /// Second source register (index register for memory ops).
    pub src2: Option<Reg>,
    /// Third source register (store data / multiply-add addend).
    pub src3: Option<Reg>,
    /// Immediate operand / memory offset.
    pub imm: i64,
    /// Condition code for `Bcond` / `Csel`.
    pub cond: Option<Condition>,
    /// Branch target (instruction index within the program).
    pub target: Option<usize>,
}

impl Instruction {
    /// A new instruction with no operands; builder-style setters fill in
    /// the rest.
    pub fn new(opcode: Opcode) -> Instruction {
        Instruction {
            opcode,
            dst: None,
            src1: None,
            src2: None,
            src3: None,
            imm: 0,
            cond: None,
            target: None,
        }
    }

    /// Set the destination register.
    pub fn dst(mut self, r: Reg) -> Self {
        self.dst = Some(r);
        self
    }

    /// Set the first source register.
    pub fn src1(mut self, r: Reg) -> Self {
        self.src1 = Some(r);
        self
    }

    /// Set the second source register.
    pub fn src2(mut self, r: Reg) -> Self {
        self.src2 = Some(r);
        self
    }

    /// Set the third source register.
    pub fn src3(mut self, r: Reg) -> Self {
        self.src3 = Some(r);
        self
    }

    /// Set the immediate operand.
    pub fn imm(mut self, v: i64) -> Self {
        self.imm = v;
        self
    }

    /// Set the condition code.
    pub fn cond(mut self, c: Condition) -> Self {
        self.cond = Some(c);
        self
    }

    /// Set the branch target (instruction index).
    pub fn target(mut self, t: usize) -> Self {
        self.target = Some(t);
        self
    }

    /// Memory access width, if this is a load/store.
    pub fn mem_width(&self) -> Option<MemWidth> {
        use Opcode::*;
        match self.opcode {
            Ldr | Str => Some(MemWidth::Double),
            Ldrw | Strw => Some(MemWidth::Word),
            Ldrb | Strb => Some(MemWidth::Byte),
            _ => None,
        }
    }

    /// Source registers actually read by this instruction, in operand
    /// order. Used for dependency tracking and the register bitmap.
    pub fn sources(&self) -> impl Iterator<Item = Reg> + '_ {
        [self.src1, self.src2, self.src3].into_iter().flatten()
    }

    /// All registers touched (sources + destination) — the paper's
    /// register bitmap includes both.
    pub fn registers(&self) -> impl Iterator<Item = Reg> + '_ {
        [self.src1, self.src2, self.src3, self.dst]
            .into_iter()
            .flatten()
    }

    /// Structural validity check; returns a description of the first
    /// problem found.
    pub fn validate(&self) -> Result<(), String> {
        let op = self.opcode;
        if op.is_load() && self.dst.is_none() {
            return Err(format!("{op}: load without destination"));
        }
        if op.is_load() && self.src1.is_none() {
            return Err(format!("{op}: load without base register"));
        }
        if op.is_store() && (self.src1.is_none() || self.src3.is_none()) {
            return Err(format!("{op}: store needs base (src1) and data (src3)"));
        }
        if op.is_branch() && op != Opcode::Ret && self.target.is_none() {
            return Err(format!("{op}: branch without target"));
        }
        if op == Opcode::Ret && self.src1.is_none() {
            return Err("ret: missing link register".into());
        }
        if matches!(op, Opcode::Bcond | Opcode::Csel) && self.cond.is_none() {
            return Err(format!("{op}: missing condition code"));
        }
        if matches!(op, Opcode::Cbz | Opcode::Cbnz | Opcode::Bcond) && self.src1.is_none() {
            return Err(format!("{op}: conditional branch without source"));
        }
        Ok(())
    }
}

impl fmt::Display for Instruction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.opcode)?;
        if let Some(c) = self.cond {
            if self.opcode == Opcode::Bcond {
                write!(f, ".{c}")?;
            }
        }
        let mut sep = " ";
        if let Some(d) = self.dst {
            write!(f, "{sep}{d}")?;
            sep = ", ";
        }
        for s in self.sources() {
            write!(f, "{sep}{s}")?;
            sep = ", ";
        }
        if self.imm != 0 || self.opcode == Opcode::Movi {
            write!(f, "{sep}#{}", self.imm)?;
            sep = ", ";
        }
        if let Some(t) = self.target {
            write!(f, "{sep}@{t}")?;
        }
        Ok(())
    }
}

/// A static program: a straight array of instructions plus an initial
/// data-memory image. Produced by `crate::workloads`, consumed by both
/// simulators.
#[derive(Debug, Clone)]
pub struct Program {
    /// Benchmark name (e.g. `"mcf"`).
    pub name: String,
    /// Static instruction array; PC of `insts[i]` is `TEXT_BASE + 4*i`.
    pub insts: Vec<Instruction>,
    /// Size of the data segment in bytes.
    pub data_size: u64,
    /// Initial 8-byte words in the data segment: `(offset, value)` pairs
    /// relative to [`DATA_BASE`].
    pub init_words: Vec<(u64, u64)>,
    /// Initial register values applied before execution.
    pub init_regs: Vec<(Reg, u64)>,
}

impl Program {
    /// PC of the instruction at `index`.
    pub fn pc_of(index: usize) -> u64 {
        TEXT_BASE + index as u64 * INST_BYTES
    }

    /// Instruction index of a PC, if it lies in this program's text.
    pub fn index_of(&self, pc: u64) -> Option<usize> {
        if pc < TEXT_BASE || (pc - TEXT_BASE) % INST_BYTES != 0 {
            return None;
        }
        let idx = ((pc - TEXT_BASE) / INST_BYTES) as usize;
        (idx < self.insts.len()).then_some(idx)
    }

    /// Number of static instructions.
    pub fn len(&self) -> usize {
        self.insts.len()
    }

    /// True if the program has no instructions.
    pub fn is_empty(&self) -> bool {
        self.insts.is_empty()
    }

    /// Validate every instruction and all branch targets.
    pub fn validate(&self) -> Result<(), String> {
        if self.insts.is_empty() {
            return Err("empty program".into());
        }
        for (i, inst) in self.insts.iter().enumerate() {
            inst.validate().map_err(|e| format!("inst {i}: {e}"))?;
            if let Some(t) = inst.target {
                if t >= self.insts.len() {
                    return Err(format!("inst {i}: branch target {t} out of range"));
                }
            }
        }
        for &(off, _) in &self.init_words {
            if off + 8 > self.data_size {
                return Err(format!("init word at {off} beyond data size {}", self.data_size));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::regs::Reg;

    fn sample_program() -> Program {
        Program {
            name: "t".into(),
            insts: vec![
                Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(5),
                Instruction::new(Opcode::Subs)
                    .dst(Reg::x(1))
                    .src1(Reg::x(1))
                    .imm(1),
                Instruction::new(Opcode::Cbnz).src1(Reg::x(1)).target(1),
                Instruction::new(Opcode::Nop),
            ],
            data_size: 64,
            init_words: vec![(0, 42)],
            init_regs: vec![],
        }
    }

    #[test]
    fn pc_index_round_trip() {
        let p = sample_program();
        for i in 0..p.len() {
            assert_eq!(p.index_of(Program::pc_of(i)), Some(i));
        }
        assert_eq!(p.index_of(TEXT_BASE - 4), None);
        assert_eq!(p.index_of(TEXT_BASE + 1), None);
        assert_eq!(p.index_of(Program::pc_of(p.len())), None);
    }

    #[test]
    fn validate_accepts_well_formed() {
        sample_program().validate().unwrap();
    }

    #[test]
    fn validate_rejects_bad_target() {
        let mut p = sample_program();
        p.insts[2].target = Some(99);
        assert!(p.validate().is_err());
    }

    #[test]
    fn validate_rejects_load_without_base() {
        let i = Instruction::new(Opcode::Ldr).dst(Reg::x(0));
        assert!(i.validate().is_err());
    }

    #[test]
    fn validate_rejects_store_without_data() {
        let i = Instruction::new(Opcode::Str).src1(Reg::x(0));
        assert!(i.validate().is_err());
    }

    #[test]
    fn validate_rejects_branch_without_target() {
        let i = Instruction::new(Opcode::B);
        assert!(i.validate().is_err());
    }

    #[test]
    fn validate_rejects_init_word_out_of_range() {
        let mut p = sample_program();
        p.init_words.push((60, 1)); // needs bytes 60..68 > 64
        assert!(p.validate().is_err());
    }

    #[test]
    fn mem_width_by_opcode() {
        assert_eq!(Instruction::new(Opcode::Ldr).mem_width(), Some(MemWidth::Double));
        assert_eq!(Instruction::new(Opcode::Strw).mem_width(), Some(MemWidth::Word));
        assert_eq!(Instruction::new(Opcode::Ldrb).mem_width(), Some(MemWidth::Byte));
        assert_eq!(Instruction::new(Opcode::Add).mem_width(), None);
    }

    #[test]
    fn registers_iterates_all_operands() {
        let i = Instruction::new(Opcode::Madd)
            .dst(Reg::x(0))
            .src1(Reg::x(1))
            .src2(Reg::x(2))
            .src3(Reg::x(3));
        let regs: Vec<Reg> = i.registers().collect();
        assert_eq!(regs.len(), 4);
        let srcs: Vec<Reg> = i.sources().collect();
        assert_eq!(srcs, vec![Reg::x(1), Reg::x(2), Reg::x(3)]);
    }

    #[test]
    fn display_is_readable() {
        let i = Instruction::new(Opcode::Bcond)
            .src1(Reg::x(1))
            .src2(Reg::x(2))
            .cond(Condition::Le)
            .target(7);
        let s = i.to_string();
        assert!(s.contains("b.cond.le"), "{s}");
        assert!(s.contains("@7"), "{s}");
    }
}
