//! TaoISA — a compact ARM-like RISC instruction set.
//!
//! This is the ISA substrate under the whole reproduction: the synthetic
//! benchmark programs (`crate::workloads`) are authored in it, the
//! functional simulator (`crate::functional`, the `AtomicSimpleCPU`
//! stand-in) interprets it, and the detailed out-of-order model
//! (`crate::detailed`, the `O3CPU` stand-in) times it.
//!
//! The paper traces SPEC CPU2017 compiled for AArch64 through gem5; the
//! DL pipeline only ever observes *static instruction properties* (opcode,
//! register set, PC, memory address) plus dynamic performance metrics, so
//! a compact ISA with the same property surface exercises every downstream
//! code path (feature engineering §4.2, dataset construction §4.1).

pub mod inst;
pub mod opcode;
pub mod regs;

pub use inst::{Instruction, MemWidth, Operand, Program};
pub use opcode::{Condition, Opcode, OpcodeClass};
pub use regs::{Reg, NUM_REGS};
