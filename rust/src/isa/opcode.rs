//! Opcodes and opcode classes for TaoISA.
//!
//! The opcode enumeration is the vocabulary of the DL model's opcode
//! embedding table (paper §4.2: "for opcode, we employ an integer mapping
//! for each unique opcode in the dataset"). `Opcode::index()` is that
//! integer mapping and is stable across runs — it is recorded in the AOT
//! artifact metadata and validated by the Rust loader.

use std::fmt;

/// Condition codes for conditional branches (`B.cond`) and conditional
/// selects (`CSEL`). Evaluated against the two source operands.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Condition {
    /// Equal.
    Eq,
    /// Not equal.
    Ne,
    /// Signed less than.
    Lt,
    /// Signed less or equal.
    Le,
    /// Signed greater than.
    Gt,
    /// Signed greater or equal.
    Ge,
}

impl Condition {
    /// All condition codes, in encoding order.
    pub const ALL: [Condition; 6] = [
        Condition::Eq,
        Condition::Ne,
        Condition::Lt,
        Condition::Le,
        Condition::Gt,
        Condition::Ge,
    ];

    /// Evaluate the condition over two signed integer operands.
    pub fn eval(self, a: i64, b: i64) -> bool {
        match self {
            Condition::Eq => a == b,
            Condition::Ne => a != b,
            Condition::Lt => a < b,
            Condition::Le => a <= b,
            Condition::Gt => a > b,
            Condition::Ge => a >= b,
        }
    }

    /// Stable encoding index.
    pub fn index(self) -> usize {
        match self {
            Condition::Eq => 0,
            Condition::Ne => 1,
            Condition::Lt => 2,
            Condition::Le => 3,
            Condition::Gt => 4,
            Condition::Ge => 5,
        }
    }

    /// Inverse of [`Condition::index`].
    pub fn from_index(i: usize) -> Condition {
        Condition::ALL[i]
    }
}

impl fmt::Display for Condition {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            Condition::Eq => "eq",
            Condition::Ne => "ne",
            Condition::Lt => "lt",
            Condition::Le => "le",
            Condition::Gt => "gt",
            Condition::Ge => "ge",
        };
        write!(f, "{s}")
    }
}

/// Coarse opcode class. Drives execution-unit selection and latency in the
/// detailed model, and instruction-mix statistics in the workload reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpcodeClass {
    /// Integer ALU (add/sub/logic/shift/compare/move).
    IntAlu,
    /// Integer multiply.
    IntMul,
    /// Integer divide.
    IntDiv,
    /// Floating point add/sub/compare/move.
    FpAlu,
    /// Floating point multiply / fused multiply-add.
    FpMul,
    /// Floating point divide / sqrt.
    FpDiv,
    /// Memory load.
    Load,
    /// Memory store.
    Store,
    /// Control flow (branches, calls, returns).
    Branch,
    /// No-operation.
    Nop,
}

/// TaoISA opcode set.
///
/// Deliberately shaped like the AArch64 subset gem5 traces expose:
/// integer/FP arithmetic, loads/stores of two widths, conditional and
/// unconditional control flow, and `NOP` (which the detailed model also
/// injects for pipeline stalls, per paper §4.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Opcode {
    // --- integer ALU ---
    Add,
    Sub,
    Adds, // add, setting flags (used before conditional branches)
    Subs, // subtract, setting flags
    Mul,
    Madd, // multiply-add
    Div,
    And,
    Orr,
    Eor,
    Lsl,
    Lsr,
    Asr,
    Cmp,
    Mov,
    Movi, // move immediate
    Csel, // conditional select
    // --- floating point ---
    Fadd,
    Fsub,
    Fmul,
    Fmadd,
    Fdiv,
    Fsqrt,
    Fcmp,
    Fmov,
    Fcvt, // int<->fp convert
    // --- memory ---
    Ldr,  // load 8 bytes
    Ldrw, // load 4 bytes
    Ldrb, // load 1 byte
    Str,  // store 8 bytes
    Strw, // store 4 bytes
    Strb, // store 1 byte
    // --- control flow ---
    B,    // unconditional branch
    Bcond, // conditional branch (B.cond)
    Cbz,  // compare-and-branch on zero
    Cbnz, // compare-and-branch on non-zero
    Bl,   // branch and link (call)
    Ret,  // return
    // --- misc ---
    Nop,
}

impl Opcode {
    /// All opcodes in stable encoding order. The position in this array is
    /// the integer opcode id used by the embedding lookup table.
    pub const ALL: [Opcode; 39] = [
        Opcode::Add,
        Opcode::Sub,
        Opcode::Adds,
        Opcode::Subs,
        Opcode::Mul,
        Opcode::Madd,
        Opcode::Div,
        Opcode::And,
        Opcode::Orr,
        Opcode::Eor,
        Opcode::Lsl,
        Opcode::Lsr,
        Opcode::Asr,
        Opcode::Cmp,
        Opcode::Mov,
        Opcode::Movi,
        Opcode::Csel,
        Opcode::Fadd,
        Opcode::Fsub,
        Opcode::Fmul,
        Opcode::Fmadd,
        Opcode::Fdiv,
        Opcode::Fsqrt,
        Opcode::Fcmp,
        Opcode::Fmov,
        Opcode::Fcvt,
        Opcode::Ldr,
        Opcode::Ldrw,
        Opcode::Ldrb,
        Opcode::Str,
        Opcode::Strw,
        Opcode::Strb,
        Opcode::B,
        Opcode::Bcond,
        Opcode::Cbz,
        Opcode::Cbnz,
        Opcode::Bl,
        Opcode::Ret,
        Opcode::Nop,
    ];

    /// Number of distinct opcodes — the opcode embedding vocabulary size.
    pub const COUNT: usize = Self::ALL.len();

    /// Stable integer id (the paper's "integer mapping for each unique
    /// opcode").
    pub fn index(self) -> usize {
        Self::ALL
            .iter()
            .position(|&op| op == self)
            .expect("opcode present in ALL")
    }

    /// Inverse of [`Opcode::index`].
    pub fn from_index(i: usize) -> Opcode {
        Self::ALL[i]
    }

    /// Coarse class of the opcode.
    pub fn class(self) -> OpcodeClass {
        use Opcode::*;
        match self {
            Add | Sub | Adds | Subs | And | Orr | Eor | Lsl | Lsr | Asr | Cmp | Mov | Movi
            | Csel => OpcodeClass::IntAlu,
            Mul | Madd => OpcodeClass::IntMul,
            Div => OpcodeClass::IntDiv,
            Fadd | Fsub | Fcmp | Fmov | Fcvt => OpcodeClass::FpAlu,
            Fmul | Fmadd => OpcodeClass::FpMul,
            Fdiv | Fsqrt => OpcodeClass::FpDiv,
            Ldr | Ldrw | Ldrb => OpcodeClass::Load,
            Str | Strw | Strb => OpcodeClass::Store,
            B | Bcond | Cbz | Cbnz | Bl | Ret => OpcodeClass::Branch,
            Nop => OpcodeClass::Nop,
        }
    }

    /// True for loads and stores.
    pub fn is_mem(self) -> bool {
        matches!(self.class(), OpcodeClass::Load | OpcodeClass::Store)
    }

    /// True for loads.
    pub fn is_load(self) -> bool {
        self.class() == OpcodeClass::Load
    }

    /// True for stores.
    pub fn is_store(self) -> bool {
        self.class() == OpcodeClass::Store
    }

    /// True for any control-flow instruction.
    pub fn is_branch(self) -> bool {
        self.class() == OpcodeClass::Branch
    }

    /// True for *conditional* control flow — the instructions the branch
    /// history feature (paper Figure 4) tracks.
    pub fn is_cond_branch(self) -> bool {
        matches!(self, Opcode::Bcond | Opcode::Cbz | Opcode::Cbnz)
    }

    /// Mnemonic for trace text output.
    pub fn mnemonic(self) -> &'static str {
        use Opcode::*;
        match self {
            Add => "add",
            Sub => "sub",
            Adds => "adds",
            Subs => "subs",
            Mul => "mul",
            Madd => "madd",
            Div => "sdiv",
            And => "and",
            Orr => "orr",
            Eor => "eor",
            Lsl => "lsl",
            Lsr => "lsr",
            Asr => "asr",
            Cmp => "cmp",
            Mov => "mov",
            Movi => "movi",
            Csel => "csel",
            Fadd => "fadd",
            Fsub => "fsub",
            Fmul => "fmul",
            Fmadd => "fmadd",
            Fdiv => "fdiv",
            Fsqrt => "fsqrt",
            Fcmp => "fcmp",
            Fmov => "fmov",
            Fcvt => "fcvt",
            Ldr => "ldr",
            Ldrw => "ldrw",
            Ldrb => "ldrb",
            Str => "str",
            Strw => "strw",
            Strb => "strb",
            B => "b",
            Bcond => "b.cond",
            Cbz => "cbz",
            Cbnz => "cbnz",
            Bl => "bl",
            Ret => "ret",
            Nop => "nop",
        }
    }
}

impl fmt::Display for Opcode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.mnemonic())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn opcode_index_round_trip() {
        for i in 0..Opcode::COUNT {
            assert_eq!(Opcode::from_index(i).index(), i);
        }
    }

    #[test]
    fn opcode_ids_are_unique() {
        let ids: HashSet<usize> = Opcode::ALL.iter().map(|op| op.index()).collect();
        assert_eq!(ids.len(), Opcode::COUNT);
    }

    #[test]
    fn mnemonics_are_unique() {
        let names: HashSet<&str> = Opcode::ALL.iter().map(|op| op.mnemonic()).collect();
        assert_eq!(names.len(), Opcode::COUNT);
    }

    #[test]
    fn class_partitions() {
        assert!(Opcode::Ldr.is_load());
        assert!(!Opcode::Ldr.is_store());
        assert!(Opcode::Strb.is_store());
        assert!(Opcode::Bcond.is_cond_branch());
        assert!(Opcode::B.is_branch());
        assert!(!Opcode::B.is_cond_branch());
        assert!(Opcode::Cbz.is_cond_branch());
        assert_eq!(Opcode::Nop.class(), OpcodeClass::Nop);
    }

    #[test]
    fn condition_eval_matrix() {
        assert!(Condition::Eq.eval(3, 3));
        assert!(!Condition::Eq.eval(3, 4));
        assert!(Condition::Ne.eval(3, 4));
        assert!(Condition::Lt.eval(-1, 0));
        assert!(Condition::Le.eval(0, 0));
        assert!(Condition::Gt.eval(5, 4));
        assert!(Condition::Ge.eval(4, 4));
        assert!(!Condition::Lt.eval(0, -1));
    }

    #[test]
    fn condition_index_round_trip() {
        for c in Condition::ALL {
            assert_eq!(Condition::from_index(c.index()), c);
        }
    }
}
