//! Architectural register file description.
//!
//! 32 general-purpose integer registers (`x0..x31`) and 16 floating point
//! registers (`f0..f15`). The feature-engineering layer builds a bitmap
//! over all `NUM_REGS` architectural registers (paper §4.2: "a bitmap
//! vector with a size equal to the total number of registers").

use std::fmt;

/// Number of integer registers (`x0..x31`). `x31` doubles as the stack
/// pointer by convention in the synthetic workloads; the ISA itself does
/// not special-case it.
pub const NUM_INT_REGS: usize = 32;
/// Number of floating-point registers (`f0..f15`).
pub const NUM_FP_REGS: usize = 16;
/// Total architectural registers — the size of the register bitmap input
/// feature.
pub const NUM_REGS: usize = NUM_INT_REGS + NUM_FP_REGS;

/// An architectural register. Integer registers occupy indices
/// `0..NUM_INT_REGS`; FP registers occupy `NUM_INT_REGS..NUM_REGS`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Reg(pub u8);

impl Reg {
    /// Integer register `xN`.
    pub const fn x(n: u8) -> Reg {
        assert!((n as usize) < NUM_INT_REGS);
        Reg(n)
    }

    /// Floating-point register `fN`.
    pub const fn f(n: u8) -> Reg {
        assert!((n as usize) < NUM_FP_REGS);
        Reg(n + NUM_INT_REGS as u8)
    }

    /// True if this is a floating-point register.
    pub fn is_fp(self) -> bool {
        (self.0 as usize) >= NUM_INT_REGS
    }

    /// Flat index into the architectural register bitmap.
    pub fn index(self) -> usize {
        self.0 as usize
    }

    /// Inverse of [`Reg::index`].
    pub fn from_index(i: usize) -> Reg {
        assert!(i < NUM_REGS, "register index {i} out of range");
        Reg(i as u8)
    }
}

impl fmt::Display for Reg {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_fp() {
            write!(f, "f{}", self.0 as usize - NUM_INT_REGS)
        } else {
            write!(f, "x{}", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn int_and_fp_registers_do_not_overlap() {
        assert_ne!(Reg::x(0), Reg::f(0));
        assert_eq!(Reg::f(0).index(), NUM_INT_REGS);
        assert_eq!(Reg::x(31).index(), 31);
        assert_eq!(Reg::f(15).index(), NUM_REGS - 1);
    }

    #[test]
    fn display_names() {
        assert_eq!(Reg::x(3).to_string(), "x3");
        assert_eq!(Reg::f(7).to_string(), "f7");
    }

    #[test]
    fn index_round_trip() {
        for i in 0..NUM_REGS {
            assert_eq!(Reg::from_index(i).index(), i);
        }
    }

    #[test]
    fn is_fp_boundary() {
        assert!(!Reg::x(31).is_fp());
        assert!(Reg::f(0).is_fp());
    }

    #[test]
    #[should_panic]
    fn from_index_out_of_range_panics() {
        let _ = Reg::from_index(NUM_REGS);
    }
}
