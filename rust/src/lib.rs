//! # tao-sim — Tao: Re-Thinking DL-based Microarchitecture Simulation
//!
//! A full-system reproduction of Tao (Pandey, Yazdanbakhsh, Liu;
//! SIGMETRICS 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! * This crate (Layer 3) holds the simulator substrate — a gem5 stand-in
//!   with functional (`AtomicSimpleCPU`) and detailed out-of-order
//!   (`O3CPU`) models — plus the trace pipeline, §4.1 dataset
//!   construction, §4.2 feature engineering, and the parallel DL-based
//!   simulation coordinator that executes AOT-compiled JAX/Pallas models
//!   via PJRT on the request path (Python is build-time only).
//! * `python/compile/` (Layers 2+1) holds the multi-metric self-attention
//!   model, the Pallas kernels, training, §4.3 transfer learning, and the
//!   AOT export to `artifacts/*.hlo.txt`.
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! mapping every paper table/figure to a module and bench target.

pub mod datagen;
pub mod cli;
pub mod coordinator;
pub mod dataset;
pub mod detailed;
pub mod dse;
pub mod features;
pub mod npy;
pub mod reports;
pub mod runtime;
pub mod sampling;
pub mod serve;
pub mod stats;
pub mod telemetry;
pub mod functional;
pub mod isa;
pub mod trace;
pub mod uarch;
pub mod util;
pub mod workloads;
