//! `tao` — CLI launcher. See `tao_sim::cli` for subcommands.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = tao_sim::cli::run(argv) {
        eprintln!("tao: error: {e:#}");
        std::process::exit(1);
    }
}
