//! Functional simulation — the `AtomicSimpleCPU` equivalent.
//!
//! [`Machine`] is the architectural core: register file, data memory, and
//! instruction semantics. The functional simulator ([`FunctionalSim`])
//! drives it one instruction per "cycle" and emits the microarchitecture
//! agnostic functional trace. The detailed out-of-order model
//! (`crate::detailed`) reuses the same `Machine` for correct-path
//! semantics so both trace kinds are guaranteed to commit the *same*
//! instruction stream — the property §4.1's alignment workflow depends on.

pub mod machine;

pub use machine::{Executed, Machine};

use crate::isa::Program;
use crate::trace::{FuncRecord, FunctionalTrace};

/// Functional simulator: executes a program atomically (1 instruction per
/// step, no timing) and records the committed stream.
pub struct FunctionalSim {
    machine: Machine,
}

impl FunctionalSim {
    /// Build a simulator over `program`.
    pub fn new(program: &Program) -> FunctionalSim {
        FunctionalSim {
            machine: Machine::new(program),
        }
    }

    /// Run up to `max_insts` instructions (or until the program halts) and
    /// return the functional trace.
    pub fn run(mut self, max_insts: u64) -> FunctionalTrace {
        let mut records = Vec::with_capacity(max_insts.min(1 << 24) as usize);
        while (records.len() as u64) < max_insts {
            match self.machine.step() {
                Some(exec) => records.push(exec.record),
                None => break,
            }
        }
        FunctionalTrace {
            name: self.machine.program_name().to_string(),
            records,
        }
    }

    /// Streaming variant: invoke `sink` per committed record; returns the
    /// number of instructions executed. Used by the coordinator's
    /// generate-and-simulate path to avoid materializing the trace.
    pub fn run_streaming(
        mut self,
        max_insts: u64,
        mut sink: impl FnMut(FuncRecord),
    ) -> u64 {
        let mut n = 0u64;
        while n < max_insts {
            match self.machine.step() {
                Some(exec) => {
                    sink(exec.record);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instruction, Opcode, Program, Reg};

    /// x1 = 5; loop { x2 += x1; x1 -= 1 } while x1 != 0
    fn countdown_program() -> Program {
        Program {
            name: "countdown".into(),
            insts: vec![
                Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(5),
                Instruction::new(Opcode::Add)
                    .dst(Reg::x(2))
                    .src1(Reg::x(2))
                    .src2(Reg::x(1)),
                Instruction::new(Opcode::Subs)
                    .dst(Reg::x(1))
                    .src1(Reg::x(1))
                    .imm(1),
                Instruction::new(Opcode::Cbnz).src1(Reg::x(1)).target(1),
                Instruction::new(Opcode::Nop),
            ],
            data_size: 0,
            init_words: vec![],
            init_regs: vec![],
        }
    }

    #[test]
    fn countdown_executes_expected_stream() {
        let p = countdown_program();
        let t = FunctionalSim::new(&p).run(1000);
        // 1 movi + 5*(add,subs,cbnz) + nop = 17, then falls off the end.
        assert_eq!(t.records.len(), 17);
        // Branch taken 4 times, not-taken once.
        let takens: Vec<bool> = t
            .records
            .iter()
            .filter(|r| r.opcode == Opcode::Cbnz)
            .map(|r| r.taken)
            .collect();
        assert_eq!(takens, vec![true, true, true, true, false]);
    }

    #[test]
    fn max_insts_truncates() {
        let p = countdown_program();
        let t = FunctionalSim::new(&p).run(7);
        assert_eq!(t.records.len(), 7);
    }

    #[test]
    fn streaming_matches_batch() {
        let p = countdown_program();
        let batch = FunctionalSim::new(&p).run(1000);
        let mut streamed = Vec::new();
        let n = FunctionalSim::new(&p).run_streaming(1000, |r| streamed.push(r));
        assert_eq!(n as usize, batch.records.len());
        assert_eq!(streamed, batch.records);
    }

    #[test]
    fn trace_is_deterministic() {
        let p = countdown_program();
        let a = FunctionalSim::new(&p).run(1000);
        let b = FunctionalSim::new(&p).run(1000);
        assert_eq!(a.records, b.records);
    }
}
