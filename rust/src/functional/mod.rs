//! Functional simulation — the `AtomicSimpleCPU` equivalent.
//!
//! [`Machine`] is the architectural core: register file, data memory, and
//! instruction semantics. The functional simulator ([`FunctionalSim`])
//! drives it one instruction per "cycle" and emits the microarchitecture
//! agnostic functional trace. The detailed out-of-order model
//! (`crate::detailed`) reuses the same `Machine` for correct-path
//! semantics so both trace kinds are guaranteed to commit the *same*
//! instruction stream — the property §4.1's alignment workflow depends on.

pub mod machine;

pub use machine::{Executed, Machine};

use crate::isa::Program;
use crate::trace::{ChunkBuf, ChunkSource, FuncRecord, FunctionalTrace};
use anyhow::{ensure, Result};

/// Functional simulator: executes a program atomically (1 instruction per
/// step, no timing) and records the committed stream.
pub struct FunctionalSim {
    machine: Machine,
}

impl FunctionalSim {
    /// Build a simulator over `program`.
    pub fn new(program: &Program) -> FunctionalSim {
        FunctionalSim {
            machine: Machine::new(program),
        }
    }

    /// Run up to `max_insts` instructions (or until the program halts) and
    /// return the functional trace.
    pub fn run(mut self, max_insts: u64) -> FunctionalTrace {
        let mut records = Vec::with_capacity(max_insts.min(1 << 24) as usize);
        while (records.len() as u64) < max_insts {
            match self.machine.step() {
                Some(exec) => records.push(exec.record),
                None => break,
            }
        }
        FunctionalTrace {
            name: self.machine.program_name().to_string(),
            records,
        }
    }

    /// Convert into a pull-based chunk source bounded by `max_insts`:
    /// the machine steps only when a consumer pulls, so
    /// simulate-while-inferring pipelines hold O(chunk) records, never
    /// the trace. (`tao simulate --stream` and the engine's
    /// `simulate_parallel_chunked` run on this.)
    pub fn into_chunks(self, max_insts: u64) -> FuncChunkSource {
        FuncChunkSource {
            machine: self.machine,
            remaining: max_insts,
        }
    }

    /// Streaming variant: invoke `sink` per committed record; returns the
    /// number of instructions executed. Used by the coordinator's
    /// generate-and-simulate path to avoid materializing the trace.
    pub fn run_streaming(
        mut self,
        max_insts: u64,
        mut sink: impl FnMut(FuncRecord),
    ) -> u64 {
        let mut n = 0u64;
        while n < max_insts {
            match self.machine.step() {
                Some(exec) => {
                    sink(exec.record);
                    n += 1;
                }
                None => break,
            }
        }
        n
    }
}

/// Generator-backed [`ChunkSource`]: commits instructions on demand,
/// straight into the pulled chunk's columns. The cheapest producer in
/// the streaming pipeline — no trace, no records vector, just the
/// architectural machine state plus the consumer's chunk buffer.
pub struct FuncChunkSource {
    machine: Machine,
    remaining: u64,
}

impl FuncChunkSource {
    /// The program name (trace name of an equivalent batch run).
    pub fn name(&self) -> &str {
        self.machine.program_name()
    }
}

impl ChunkSource for FuncChunkSource {
    fn len_hint(&self) -> Option<usize> {
        // Upper bound: the program may halt before the budget runs out.
        Some(self.remaining as usize)
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        buf.clear();
        let n = (max_rows as u64).min(self.remaining);
        for _ in 0..n {
            match self.machine.step() {
                Some(exec) => {
                    buf.cols.push(&exec.record);
                    self.remaining -= 1;
                }
                None => {
                    self.remaining = 0;
                    break;
                }
            }
        }
        Ok(buf.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Instruction, Opcode, Program, Reg};

    /// x1 = 5; loop { x2 += x1; x1 -= 1 } while x1 != 0
    fn countdown_program() -> Program {
        Program {
            name: "countdown".into(),
            insts: vec![
                Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(5),
                Instruction::new(Opcode::Add)
                    .dst(Reg::x(2))
                    .src1(Reg::x(2))
                    .src2(Reg::x(1)),
                Instruction::new(Opcode::Subs)
                    .dst(Reg::x(1))
                    .src1(Reg::x(1))
                    .imm(1),
                Instruction::new(Opcode::Cbnz).src1(Reg::x(1)).target(1),
                Instruction::new(Opcode::Nop),
            ],
            data_size: 0,
            init_words: vec![],
            init_regs: vec![],
        }
    }

    #[test]
    fn countdown_executes_expected_stream() {
        let p = countdown_program();
        let t = FunctionalSim::new(&p).run(1000);
        // 1 movi + 5*(add,subs,cbnz) + nop = 17, then falls off the end.
        assert_eq!(t.records.len(), 17);
        // Branch taken 4 times, not-taken once.
        let takens: Vec<bool> = t
            .records
            .iter()
            .filter(|r| r.opcode == Opcode::Cbnz)
            .map(|r| r.taken)
            .collect();
        assert_eq!(takens, vec![true, true, true, true, false]);
    }

    #[test]
    fn max_insts_truncates() {
        let p = countdown_program();
        let t = FunctionalSim::new(&p).run(7);
        assert_eq!(t.records.len(), 7);
    }

    #[test]
    fn streaming_matches_batch() {
        let p = countdown_program();
        let batch = FunctionalSim::new(&p).run(1000);
        let mut streamed = Vec::new();
        let n = FunctionalSim::new(&p).run_streaming(1000, |r| streamed.push(r));
        assert_eq!(n as usize, batch.records.len());
        assert_eq!(streamed, batch.records);
    }

    #[test]
    fn chunk_source_matches_batch_run() {
        let p = countdown_program();
        let batch = FunctionalSim::new(&p).run(1000);
        let mut src = FunctionalSim::new(&p).into_chunks(1000);
        assert_eq!(src.name(), "countdown");
        let mut buf = ChunkBuf::new();
        let mut streamed = Vec::new();
        loop {
            let n = src.next_chunk(&mut buf, 5).unwrap();
            if n == 0 {
                break;
            }
            streamed.extend(buf.cols.iter());
        }
        // The program halts at 17 instructions: the source stops there
        // too, budget notwithstanding.
        assert_eq!(streamed, batch.records);
        assert_eq!(src.len_hint(), Some(0));
        assert!(src.next_chunk(&mut buf, 0).is_err());
    }

    #[test]
    fn trace_is_deterministic() {
        let p = countdown_program();
        let a = FunctionalSim::new(&p).run(1000);
        let b = FunctionalSim::new(&p).run(1000);
        assert_eq!(a.records, b.records);
    }
}
