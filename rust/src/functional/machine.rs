//! Architectural machine state and TaoISA instruction semantics.

use crate::isa::inst::{DATA_BASE, INST_BYTES, TEXT_BASE};
use crate::isa::{Instruction, Opcode, Program, Reg, NUM_REGS};
use crate::trace::FuncRecord;

/// One executed instruction: its committed record plus control-flow info
/// the detailed model needs (the index executed and where control went).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Executed {
    /// Static instruction index that was executed.
    pub index: usize,
    /// Index control flow proceeds to (`None` = program halted).
    pub next_index: Option<usize>,
    /// The functional-trace record for this instruction.
    pub record: FuncRecord,
}

/// Architectural state: registers, data memory, and the program counter
/// (as a static instruction index). Executes one instruction per
/// [`Machine::step`], with full TaoISA semantics.
pub struct Machine {
    program: Program,
    /// Register file. Integer registers hold `i64` bit patterns; FP
    /// registers hold `f64` bit patterns.
    regs: [u64; NUM_REGS],
    /// Flat data segment.
    mem: Vec<u8>,
    /// Current instruction index (`None` once halted).
    pc_index: Option<usize>,
    /// Committed instruction count.
    committed: u64,
}

impl Machine {
    /// Build a machine, applying the program's initial memory and register
    /// image.
    pub fn new(program: &Program) -> Machine {
        let mut mem = vec![0u8; program.data_size as usize];
        for &(off, val) in &program.init_words {
            mem[off as usize..off as usize + 8].copy_from_slice(&val.to_le_bytes());
        }
        let mut regs = [0u64; NUM_REGS];
        for &(r, v) in &program.init_regs {
            regs[r.index()] = v;
        }
        Machine {
            program: program.clone(),
            regs,
            mem,
            pc_index: if program.insts.is_empty() { None } else { Some(0) },
            committed: 0,
        }
    }

    /// Benchmark name of the loaded program.
    pub fn program_name(&self) -> &str {
        &self.program.name
    }

    /// The loaded program.
    pub fn program(&self) -> &Program {
        &self.program
    }

    /// Current instruction index, `None` if halted.
    pub fn pc_index(&self) -> Option<usize> {
        self.pc_index
    }

    /// Committed instruction count so far.
    pub fn committed(&self) -> u64 {
        self.committed
    }

    /// Read an integer register as a signed value.
    pub fn read_int(&self, r: Reg) -> i64 {
        self.regs[r.index()] as i64
    }

    /// Read an FP register.
    pub fn read_fp(&self, r: Reg) -> f64 {
        f64::from_bits(self.regs[r.index()])
    }

    fn write_int(&mut self, r: Reg, v: i64) {
        self.regs[r.index()] = v as u64;
    }

    fn write_fp(&mut self, r: Reg, v: f64) {
        self.regs[r.index()] = v.to_bits();
    }

    /// Effective address of a memory instruction under the current state,
    /// clamped into the data segment and aligned to the access width.
    /// Exposed so the detailed model can compute addresses at issue time.
    pub fn effective_addr(&self, inst: &Instruction) -> u64 {
        let base = inst.src1.map(|r| self.regs[r.index()]).unwrap_or(0);
        let index = inst.src2.map(|r| self.regs[r.index()]).unwrap_or(0);
        let width = inst.mem_width().map(|w| w.bytes()).unwrap_or(1);
        let raw = base
            .wrapping_add(index)
            .wrapping_add(inst.imm as u64);
        let size = self.mem.len() as u64;
        if size == 0 {
            return DATA_BASE;
        }
        // Clamp into [DATA_BASE, DATA_BASE+size) and align.
        let off = raw.wrapping_sub(DATA_BASE) % size;
        let off = off - off % width;
        let off = off.min(size - width);
        DATA_BASE + off
    }

    fn load(&self, addr: u64, bytes: u64) -> u64 {
        let off = (addr - DATA_BASE) as usize;
        let mut buf = [0u8; 8];
        buf[..bytes as usize].copy_from_slice(&self.mem[off..off + bytes as usize]);
        u64::from_le_bytes(buf)
    }

    fn store(&mut self, addr: u64, bytes: u64, val: u64) {
        let off = (addr - DATA_BASE) as usize;
        self.mem[off..off + bytes as usize].copy_from_slice(&val.to_le_bytes()[..bytes as usize]);
    }

    fn alu_src2(&self, inst: &Instruction) -> i64 {
        match inst.src2 {
            Some(r) => self.read_int(r),
            None => inst.imm,
        }
    }

    fn fp_src2(&self, inst: &Instruction) -> f64 {
        match inst.src2 {
            Some(r) => self.read_fp(r),
            None => inst.imm as f64,
        }
    }

    /// Execute the instruction at the current PC. Returns `None` once the
    /// machine halts (control falls off the end of the program).
    pub fn step(&mut self) -> Option<Executed> {
        let index = self.pc_index?;
        let inst = self.program.insts[index];
        let pc = TEXT_BASE + index as u64 * INST_BYTES;

        let mut mem_addr = 0u64;
        let mut mem_bytes = 0u8;
        let mut taken = false;
        // Default fallthrough.
        let mut next = index + 1;

        use Opcode::*;
        match inst.opcode {
            Add | Adds => {
                let v = self.read_int(inst.src1.unwrap()).wrapping_add(self.alu_src2(&inst));
                self.write_int(inst.dst.unwrap(), v);
            }
            Sub | Subs | Cmp => {
                let v = self.read_int(inst.src1.unwrap()).wrapping_sub(self.alu_src2(&inst));
                if let Some(d) = inst.dst {
                    self.write_int(d, v);
                }
            }
            Mul => {
                let v = self.read_int(inst.src1.unwrap()).wrapping_mul(self.alu_src2(&inst));
                self.write_int(inst.dst.unwrap(), v);
            }
            Madd => {
                let v = self
                    .read_int(inst.src1.unwrap())
                    .wrapping_mul(self.alu_src2(&inst))
                    .wrapping_add(inst.src3.map(|r| self.read_int(r)).unwrap_or(0));
                self.write_int(inst.dst.unwrap(), v);
            }
            Div => {
                let a = self.read_int(inst.src1.unwrap());
                let b = self.alu_src2(&inst);
                let v = if b == 0 { 0 } else { a.wrapping_div(b) };
                self.write_int(inst.dst.unwrap(), v);
            }
            And => {
                let v = self.read_int(inst.src1.unwrap()) & self.alu_src2(&inst);
                self.write_int(inst.dst.unwrap(), v);
            }
            Orr => {
                let v = self.read_int(inst.src1.unwrap()) | self.alu_src2(&inst);
                self.write_int(inst.dst.unwrap(), v);
            }
            Eor => {
                let v = self.read_int(inst.src1.unwrap()) ^ self.alu_src2(&inst);
                self.write_int(inst.dst.unwrap(), v);
            }
            Lsl => {
                let v = (self.read_int(inst.src1.unwrap()) as u64)
                    .wrapping_shl(self.alu_src2(&inst) as u32 & 63);
                self.write_int(inst.dst.unwrap(), v as i64);
            }
            Lsr => {
                let v = (self.read_int(inst.src1.unwrap()) as u64)
                    .wrapping_shr(self.alu_src2(&inst) as u32 & 63);
                self.write_int(inst.dst.unwrap(), v as i64);
            }
            Asr => {
                let v = self
                    .read_int(inst.src1.unwrap())
                    .wrapping_shr(self.alu_src2(&inst) as u32 & 63);
                self.write_int(inst.dst.unwrap(), v);
            }
            Mov => {
                let v = self.read_int(inst.src1.unwrap());
                self.write_int(inst.dst.unwrap(), v);
            }
            Movi => {
                self.write_int(inst.dst.unwrap(), inst.imm);
            }
            Csel => {
                let c = inst.cond.unwrap();
                let a = self.read_int(inst.src1.unwrap());
                let b = inst.src2.map(|r| self.read_int(r)).unwrap_or(inst.imm);
                let v = if c.eval(a, b) { a } else { b };
                self.write_int(inst.dst.unwrap(), v);
            }
            Fadd => {
                let v = self.read_fp(inst.src1.unwrap()) + self.fp_src2(&inst);
                self.write_fp(inst.dst.unwrap(), v);
            }
            Fsub => {
                let v = self.read_fp(inst.src1.unwrap()) - self.fp_src2(&inst);
                self.write_fp(inst.dst.unwrap(), v);
            }
            Fmul => {
                let v = self.read_fp(inst.src1.unwrap()) * self.fp_src2(&inst);
                self.write_fp(inst.dst.unwrap(), v);
            }
            Fmadd => {
                let v = self.read_fp(inst.src1.unwrap()) * self.fp_src2(&inst)
                    + inst.src3.map(|r| self.read_fp(r)).unwrap_or(0.0);
                self.write_fp(inst.dst.unwrap(), v);
            }
            Fdiv => {
                let b = self.fp_src2(&inst);
                let v = if b == 0.0 {
                    0.0
                } else {
                    self.read_fp(inst.src1.unwrap()) / b
                };
                self.write_fp(inst.dst.unwrap(), v);
            }
            Fsqrt => {
                let v = self.read_fp(inst.src1.unwrap()).abs().sqrt();
                self.write_fp(inst.dst.unwrap(), v);
            }
            Fcmp => {
                let v = (self.read_fp(inst.src1.unwrap()) - self.fp_src2(&inst)).signum();
                self.write_int(inst.dst.unwrap(), v as i64);
            }
            Fmov => {
                let v = self.read_fp(inst.src1.unwrap());
                self.write_fp(inst.dst.unwrap(), v);
            }
            Fcvt => {
                // Direction from register kinds: int->fp or fp->int.
                let s = inst.src1.unwrap();
                let d = inst.dst.unwrap();
                if d.is_fp() {
                    let v = self.read_int(s) as f64;
                    self.write_fp(d, v);
                } else {
                    let v = self.read_fp(s);
                    let v = if v.is_finite() { v as i64 } else { 0 };
                    self.write_int(d, v);
                }
            }
            Ldr | Ldrw | Ldrb => {
                let width = inst.mem_width().unwrap().bytes();
                mem_addr = self.effective_addr(&inst);
                mem_bytes = width as u8;
                let v = self.load(mem_addr, width);
                let d = inst.dst.unwrap();
                if d.is_fp() {
                    self.regs[d.index()] = v;
                } else {
                    self.write_int(d, v as i64);
                }
            }
            Str | Strw | Strb => {
                let width = inst.mem_width().unwrap().bytes();
                mem_addr = self.effective_addr(&inst);
                mem_bytes = width as u8;
                let v = self.regs[inst.src3.unwrap().index()];
                self.store(mem_addr, width, v);
            }
            B => {
                taken = true;
                next = inst.target.unwrap();
            }
            Bl => {
                taken = true;
                self.write_int(inst.dst.unwrap_or(Reg::x(30)), (index + 1) as i64);
                next = inst.target.unwrap();
            }
            Ret => {
                taken = true;
                let t = self.read_int(inst.src1.unwrap());
                next = if t >= 0 && (t as usize) < self.program.insts.len() {
                    t as usize
                } else {
                    self.program.insts.len() // halt
                };
            }
            Bcond => {
                let a = self.read_int(inst.src1.unwrap());
                let b = inst.src2.map(|r| self.read_int(r)).unwrap_or(inst.imm);
                taken = inst.cond.unwrap().eval(a, b);
                if taken {
                    next = inst.target.unwrap();
                }
            }
            Cbz => {
                taken = self.read_int(inst.src1.unwrap()) == 0;
                if taken {
                    next = inst.target.unwrap();
                }
            }
            Cbnz => {
                taken = self.read_int(inst.src1.unwrap()) != 0;
                if taken {
                    next = inst.target.unwrap();
                }
            }
            Nop => {}
        }

        let mut reg_bitmap = 0u64;
        for r in inst.registers() {
            reg_bitmap |= 1u64 << r.index();
        }

        self.committed += 1;
        let next_index = if next < self.program.insts.len() {
            Some(next)
        } else {
            None
        };
        self.pc_index = next_index;

        Some(Executed {
            index,
            next_index,
            record: FuncRecord {
                pc,
                opcode: inst.opcode,
                reg_bitmap,
                mem_addr,
                mem_bytes,
                taken,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::{Condition, Instruction, Opcode, Program, Reg};

    fn prog(insts: Vec<Instruction>) -> Program {
        Program {
            name: "t".into(),
            insts,
            data_size: 256,
            init_words: vec![(0, 0xDEADBEEF), (8, 7)],
            init_regs: vec![],
        }
    }

    fn run_machine(p: &Program, steps: usize) -> Machine {
        let mut m = Machine::new(p);
        for _ in 0..steps {
            if m.step().is_none() {
                break;
            }
        }
        m
    }

    #[test]
    fn arithmetic_semantics() {
        let p = prog(vec![
            Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(6),
            Instruction::new(Opcode::Movi).dst(Reg::x(2)).imm(7),
            Instruction::new(Opcode::Mul)
                .dst(Reg::x(3))
                .src1(Reg::x(1))
                .src2(Reg::x(2)),
            Instruction::new(Opcode::Madd)
                .dst(Reg::x(4))
                .src1(Reg::x(1))
                .src2(Reg::x(2))
                .src3(Reg::x(3)),
            Instruction::new(Opcode::Div)
                .dst(Reg::x(5))
                .src1(Reg::x(3))
                .imm(6),
        ]);
        let m = run_machine(&p, 10);
        assert_eq!(m.read_int(Reg::x(3)), 42);
        assert_eq!(m.read_int(Reg::x(4)), 84);
        assert_eq!(m.read_int(Reg::x(5)), 7);
    }

    #[test]
    fn divide_by_zero_yields_zero() {
        let p = prog(vec![
            Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(5),
            Instruction::new(Opcode::Div)
                .dst(Reg::x(2))
                .src1(Reg::x(1))
                .src2(Reg::x(3)), // x3 == 0
        ]);
        let m = run_machine(&p, 2);
        assert_eq!(m.read_int(Reg::x(2)), 0);
    }

    #[test]
    fn load_store_round_trip() {
        let p = prog(vec![
            Instruction::new(Opcode::Movi)
                .dst(Reg::x(1))
                .imm(crate::isa::inst::DATA_BASE as i64),
            Instruction::new(Opcode::Movi).dst(Reg::x(2)).imm(1234),
            Instruction::new(Opcode::Str)
                .src1(Reg::x(1))
                .imm(16)
                .src3(Reg::x(2)),
            Instruction::new(Opcode::Ldr)
                .dst(Reg::x(3))
                .src1(Reg::x(1))
                .imm(16),
        ]);
        let m = run_machine(&p, 4);
        assert_eq!(m.read_int(Reg::x(3)), 1234);
    }

    #[test]
    fn init_words_visible_to_loads() {
        let p = prog(vec![
            Instruction::new(Opcode::Movi)
                .dst(Reg::x(1))
                .imm(crate::isa::inst::DATA_BASE as i64),
            Instruction::new(Opcode::Ldr)
                .dst(Reg::x(2))
                .src1(Reg::x(1))
                .imm(8),
        ]);
        let m = run_machine(&p, 2);
        assert_eq!(m.read_int(Reg::x(2)), 7);
    }

    #[test]
    fn byte_store_masks() {
        let p = prog(vec![
            Instruction::new(Opcode::Movi)
                .dst(Reg::x(1))
                .imm(crate::isa::inst::DATA_BASE as i64),
            Instruction::new(Opcode::Movi).dst(Reg::x(2)).imm(0x1FF),
            Instruction::new(Opcode::Strb)
                .src1(Reg::x(1))
                .imm(32)
                .src3(Reg::x(2)),
            Instruction::new(Opcode::Ldrb)
                .dst(Reg::x(3))
                .src1(Reg::x(1))
                .imm(32),
        ]);
        let m = run_machine(&p, 4);
        assert_eq!(m.read_int(Reg::x(3)), 0xFF);
    }

    #[test]
    fn fp_semantics() {
        let p = prog(vec![
            Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(3),
            Instruction::new(Opcode::Fcvt).dst(Reg::f(0)).src1(Reg::x(1)),
            Instruction::new(Opcode::Fmul)
                .dst(Reg::f(1))
                .src1(Reg::f(0))
                .src2(Reg::f(0)),
            Instruction::new(Opcode::Fsqrt).dst(Reg::f(2)).src1(Reg::f(1)),
            Instruction::new(Opcode::Fcvt).dst(Reg::x(2)).src1(Reg::f(2)),
        ]);
        let m = run_machine(&p, 5);
        assert_eq!(m.read_int(Reg::x(2)), 3);
        assert!((m.read_fp(Reg::f(1)) - 9.0).abs() < 1e-12);
    }

    #[test]
    fn conditional_branch_taken_and_not() {
        let p = prog(vec![
            Instruction::new(Opcode::Movi).dst(Reg::x(1)).imm(1),
            Instruction::new(Opcode::Bcond)
                .src1(Reg::x(1))
                .imm(0)
                .cond(Condition::Gt)
                .target(3),
            Instruction::new(Opcode::Movi).dst(Reg::x(2)).imm(111), // skipped
            Instruction::new(Opcode::Movi).dst(Reg::x(3)).imm(222),
        ]);
        let m = run_machine(&p, 10);
        assert_eq!(m.read_int(Reg::x(2)), 0);
        assert_eq!(m.read_int(Reg::x(3)), 222);
    }

    #[test]
    fn call_and_return() {
        // 0: bl @3 ; 1: movi x5, 99 ; 2: b @5(end) ; 3: movi x4, 7 ; 4: ret x30; 5: nop
        let p = prog(vec![
            Instruction::new(Opcode::Bl).dst(Reg::x(30)).target(3),
            Instruction::new(Opcode::Movi).dst(Reg::x(5)).imm(99),
            Instruction::new(Opcode::B).target(5),
            Instruction::new(Opcode::Movi).dst(Reg::x(4)).imm(7),
            Instruction::new(Opcode::Ret).src1(Reg::x(30)),
            Instruction::new(Opcode::Nop),
        ]);
        let m = run_machine(&p, 20);
        assert_eq!(m.read_int(Reg::x(4)), 7);
        assert_eq!(m.read_int(Reg::x(5)), 99);
        assert_eq!(m.committed(), 6);
    }

    #[test]
    fn halts_at_program_end() {
        let p = prog(vec![Instruction::new(Opcode::Nop)]);
        let mut m = Machine::new(&p);
        assert!(m.step().is_some());
        assert!(m.step().is_none());
        assert_eq!(m.pc_index(), None);
    }

    #[test]
    fn effective_addr_clamped_and_aligned() {
        let p = prog(vec![Instruction::new(Opcode::Nop)]);
        let m = Machine::new(&p);
        let inst = Instruction::new(Opcode::Ldr)
            .dst(Reg::x(0))
            .src1(Reg::x(9)) // x9 = 0 -> raw addr way below DATA_BASE
            .imm(3); // misaligned
        let addr = m.effective_addr(&inst);
        assert!(addr >= crate::isa::inst::DATA_BASE);
        assert!(addr + 8 <= crate::isa::inst::DATA_BASE + 256);
        assert_eq!(addr % 8, 0);
    }
}
