//! Training-data generation: benchmark → traces → §4.1 adjustment →
//! §4.2 features → `.npy` arrays for the Python (build-time) trainer.
//!
//! This is the bridge between the Rust substrate and Layer 2: it runs the
//! detailed and functional simulators, aligns and adjusts the traces, runs
//! the feature extractor, and emits per-(µarch, benchmark) arrays:
//!
//! * `opcodes.npy` — `i32[M]` opcode ids;
//! * `features.npy` — `f32[M, F]` per-instruction feature vectors;
//! * `labels.npy` — `f32[M, 6]`: fetch latency, exec latency, branch
//!   mispredict, access level, icache miss, TLB miss.
//!
//! plus a `meta.json` with the feature configuration and opcode
//! vocabulary that the AOT artifact must echo back (validated by the
//! runtime loader).

use crate::dataset::{self, AdjustedTrace};
use crate::detailed::DetailedSim;
use crate::features::{FeatureConfig, FeatureExtractor};
use crate::functional::FunctionalSim;
use crate::npy;
use crate::uarch::UarchConfig;
use crate::workloads::Workload;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;

/// Number of label columns in `labels.npy`.
pub const NUM_LABELS: usize = 6;

/// Options for a datagen run.
#[derive(Debug, Clone)]
pub struct DatagenOptions {
    /// Instructions per (µarch, benchmark) pair.
    pub instructions: u64,
    /// Feature-engineering hyperparameters.
    pub features: FeatureConfig,
    /// Workload seed.
    pub seed: u64,
}

impl Default for DatagenOptions {
    fn default() -> Self {
        DatagenOptions {
            instructions: 20_000,
            features: FeatureConfig::default(),
            seed: 42,
        }
    }
}

/// The in-memory form of one generated dataset.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    /// Opcode ids, one per instruction.
    pub opcodes: Vec<i32>,
    /// Flattened `[M, F]` feature matrix.
    pub features: Vec<f32>,
    /// Feature dimension `F`.
    pub feature_dim: usize,
    /// Flattened `[M, NUM_LABELS]` label matrix.
    pub labels: Vec<f32>,
    /// Ground-truth total cycles of the run.
    pub total_cycles: u64,
}

impl Dataset {
    /// Number of instructions `M`.
    pub fn len(&self) -> usize {
        self.opcodes.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.opcodes.is_empty()
    }
}

/// Generate the aligned, adjusted trace for one (benchmark, µarch) pair.
pub fn adjusted_trace(
    workload: &Workload,
    uarch: &UarchConfig,
    instructions: u64,
    seed: u64,
) -> Result<AdjustedTrace> {
    let program = workload.build(seed);
    let functional = FunctionalSim::new(&program).run(instructions);
    let (detailed, _) = DetailedSim::new(&program, uarch).run(instructions);
    let adjusted = dataset::adjust(&detailed);
    dataset::align(&functional, adjusted)
}

/// Build the feature/label arrays from an adjusted trace.
pub fn featurize(adjusted: &AdjustedTrace, config: FeatureConfig) -> Dataset {
    let f = config.feature_dim();
    let m = adjusted.samples.len();
    let mut ds = Dataset {
        opcodes: Vec::with_capacity(m),
        features: vec![0.0; m * f],
        feature_dim: f,
        labels: Vec::with_capacity(m * NUM_LABELS),
        total_cycles: adjusted.total_cycles,
    };
    let mut fx = FeatureExtractor::new(config);
    for (i, s) in adjusted.samples.iter().enumerate() {
        // Zero-copy: the extractor writes the row straight into the
        // dataset matrix.
        let id = fx.extract_into(&s.func, &mut ds.features[i * f..(i + 1) * f]);
        ds.opcodes.push(id);
        let l = &s.labels;
        ds.labels.extend_from_slice(&[
            l.fetch_latency as f32,
            l.exec_latency as f32,
            l.branch_mispred as u8 as f32,
            l.access_level.index() as f32,
            l.icache_miss as u8 as f32,
            l.tlb_miss as u8 as f32,
        ]);
    }
    ds
}

/// Generate and featurize in one step.
pub fn generate(
    workload: &Workload,
    uarch: &UarchConfig,
    opts: &DatagenOptions,
) -> Result<Dataset> {
    let adjusted = adjusted_trace(workload, uarch, opts.instructions, opts.seed)?;
    Ok(featurize(&adjusted, opts.features))
}

/// Write one dataset under `dir/<uarch>/<bench>/`.
pub fn write_dataset(dir: &Path, uarch: &str, bench: &str, ds: &Dataset) -> Result<()> {
    let d = dir.join(uarch).join(bench);
    std::fs::create_dir_all(&d).with_context(|| format!("mkdir {d:?}"))?;
    npy::write_i32_1d(&d.join("opcodes.npy"), &ds.opcodes)?;
    npy::write_f32_2d(&d.join("features.npy"), &ds.features, ds.len(), ds.feature_dim)?;
    npy::write_f32_2d(&d.join("labels.npy"), &ds.labels, ds.len(), NUM_LABELS)?;
    std::fs::write(
        d.join("total_cycles.txt"),
        format!("{}\n", ds.total_cycles),
    )?;
    Ok(())
}

/// Write the run-level metadata JSON (feature config + opcode vocab).
pub fn write_meta(dir: &Path, opts: &DatagenOptions, uarchs: &[&UarchConfig]) -> Result<()> {
    let mut f = std::fs::File::create(dir.join("meta.json"))?;
    let vocab: Vec<String> = crate::features::opcode_vocabulary()
        .iter()
        .map(|(name, idx)| format!("    \"{name}\": {idx}"))
        .collect();
    let uarch_list: Vec<String> = uarchs
        .iter()
        .map(|u| format!("    \"{}\": \"{}\"", u.name, u.summary()))
        .collect();
    writeln!(
        f,
        "{{\n  \"instructions\": {},\n  \"seed\": {},\n  \"feature_config\": {{\"nb\": {}, \"nq\": {}, \"nm\": {}}},\n  \"feature_dim\": {},\n  \"num_labels\": {},\n  \"num_regs\": {},\n  \"opcode_vocab\": {{\n{}\n  }},\n  \"uarchs\": {{\n{}\n  }}\n}}",
        opts.instructions,
        opts.seed,
        opts.features.nb,
        opts.features.nq,
        opts.features.nm,
        opts.features.feature_dim(),
        NUM_LABELS,
        crate::isa::NUM_REGS,
        vocab.join(",\n"),
        uarch_list.join(",\n"),
    )?;
    Ok(())
}

/// Full datagen run: all benchmarks in `workloads` × all `uarchs`.
pub fn run(
    dir: &Path,
    workloads: &[Workload],
    uarchs: &[UarchConfig],
    opts: &DatagenOptions,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let refs: Vec<&UarchConfig> = uarchs.iter().collect();
    write_meta(dir, opts, &refs)?;
    for uarch in uarchs {
        for w in workloads {
            let ds = generate(w, uarch, opts)?;
            write_dataset(dir, &uarch.name, w.name, &ds)?;
            eprintln!(
                "datagen: {}/{} — {} insts, {} cycles (cpi {:.3})",
                uarch.name,
                w.name,
                ds.len(),
                ds.total_cycles,
                ds.total_cycles as f64 / ds.len().max(1) as f64
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn opts() -> DatagenOptions {
        DatagenOptions {
            instructions: 2_000,
            ..Default::default()
        }
    }

    #[test]
    fn generate_shapes_consistent() {
        let w = workloads::by_name("dee").unwrap();
        let ds = generate(&w, &UarchConfig::uarch_a(), &opts()).unwrap();
        assert_eq!(ds.len(), 2_000);
        assert_eq!(ds.features.len(), ds.len() * ds.feature_dim);
        assert_eq!(ds.labels.len(), ds.len() * NUM_LABELS);
        assert!(ds.total_cycles > 0);
    }

    #[test]
    fn labels_reconstruct_total_cycles() {
        let w = workloads::by_name("lee").unwrap();
        let ds = generate(&w, &UarchConfig::uarch_b(), &opts()).unwrap();
        let total = crate::dataset::reconstruct_cycles(
            ds.labels.chunks(NUM_LABELS).map(|l| l[0] as f64),
            ds.labels.chunks(NUM_LABELS).map(|l| l[1] as f64),
        );
        assert_eq!(total, ds.total_cycles);
    }

    #[test]
    fn write_and_read_back() {
        let dir = std::env::temp_dir().join(format!("tao-dg-{}", std::process::id()));
        let w = workloads::by_name("nab").unwrap();
        let ds = generate(&w, &UarchConfig::uarch_a(), &opts()).unwrap();
        write_dataset(&dir, "uarch_a", "nab", &ds).unwrap();
        let feats = npy::read(&dir.join("uarch_a/nab/features.npy")).unwrap();
        assert_eq!(feats.shape, vec![ds.len(), ds.feature_dim]);
        let ops = npy::read(&dir.join("uarch_a/nab/opcodes.npy")).unwrap();
        assert_eq!(ops.as_i32().unwrap(), ds.opcodes);
    }

    #[test]
    fn meta_json_is_parseable_shape() {
        let dir = std::env::temp_dir().join(format!("tao-dgm-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let a = UarchConfig::uarch_a();
        write_meta(&dir, &opts(), &[&a]).unwrap();
        let s = std::fs::read_to_string(dir.join("meta.json")).unwrap();
        assert!(s.contains("\"feature_dim\""));
        assert!(s.contains("\"opcode_vocab\""));
        // Must at least be balanced braces.
        assert_eq!(s.matches('{').count(), s.matches('}').count());
    }

    #[test]
    fn different_uarchs_give_different_labels() {
        let w = workloads::by_name("mcf").unwrap();
        let a = generate(&w, &UarchConfig::uarch_a(), &opts()).unwrap();
        let c = generate(&w, &UarchConfig::uarch_c(), &opts()).unwrap();
        // Same inputs (µarch-agnostic)...
        assert_eq!(a.opcodes, c.opcodes);
        assert_eq!(a.features, c.features);
        // ...different labels (µarch-specific).
        assert_ne!(a.labels, c.labels);
    }
}
