//! Training-data generation: benchmark → traces → §4.1 adjustment →
//! §4.2 features → `.npy` arrays for the Python (build-time) trainer.
//!
//! This is the bridge between the Rust substrate and Layer 2: it runs the
//! detailed and functional simulators, aligns and adjusts the traces, runs
//! the feature extractor, and emits per-(µarch, benchmark) arrays:
//!
//! * `opcodes.npy` — `i32[M]` opcode ids;
//! * `features.npy` — `f32[M, F]` per-instruction feature vectors;
//! * `labels.npy` — `f32[M, 6]`: fetch latency, exec latency, branch
//!   mispredict, access level, icache miss, TLB miss.
//!
//! plus a `meta.json` with the feature configuration and opcode
//! vocabulary that the AOT artifact must echo back (validated by the
//! runtime loader).
//!
//! # Streaming, sharded generation
//!
//! At paper scale (hundreds of millions of instructions) the `[M, F]`
//! feature matrix does not fit in RAM, so the default path is
//! [`stream_dataset`]: K shard workers pull contiguous shards off an
//! atomic-cursor work queue (the same pattern as
//! `coordinator::engine::simulate_parallel`), warm their extractor to the
//! shard start with the exact state-only
//! [`FeatureExtractor::advance`] fast path, then stream the shard
//! chunk-by-chunk — per-chunk §4.1 alignment, per-chunk featurization
//! into a reused `chunk × F` buffer, per-chunk appends through the
//! incremental [`npy::NpyWriter`] — into `features_NNN.npy` /
//! `opcodes_NNN.npy` / `labels_NNN.npy` plus a `manifest.json`.
//! [`merge_shards`] then reassembles the canonical single-file arrays
//! through fixed-size copy buffers. Peak buffering is O(chunk × F) per
//! worker regardless of trace length, and because the warm-up is exact
//! (not approximate), the sharded output is **byte-identical** to the
//! in-memory [`featurize`] + [`write_dataset`] path — enforced by tests.

use crate::coordinator::pipeline::{PipeMsg, StagePipeline};
use crate::dataset::{self, AdjustedTrace, Labels, Sample};
use crate::detailed::DetailedSim;
use crate::features::{FeatureConfig, FeatureExtractor};
use crate::functional::{FunctionalSim, Machine};
use crate::npy::{self, Dtype, NpyWriter};
use crate::trace::{ChunkBuf, ChunkSource, RecordSource, LABEL_WIDTH};
use crate::uarch::UarchConfig;
use crate::workloads::Workload;
use anyhow::{bail, ensure, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of label columns in `labels.npy`. Pinned to the chunk
/// pipeline's label-channel width: a [`ChunkSource`] label row *is* a
/// `labels.npy` row.
pub const NUM_LABELS: usize = LABEL_WIDTH;

/// Streaming knobs for the sharded datagen writer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamOptions {
    /// Rows featurized and appended at a time. Peak buffering is
    /// O(`chunk_size` × F) per worker, independent of trace length.
    pub chunk_size: usize,
    /// Shard files per array; workers stream shards off a work queue.
    pub shards: usize,
    /// Keep the per-shard files + `manifest.json` next to the merged
    /// canonical arrays instead of deleting them after the merge.
    pub keep_shards: bool,
}

impl Default for StreamOptions {
    fn default() -> StreamOptions {
        StreamOptions {
            chunk_size: 8_192,
            shards: 1,
            keep_shards: false,
        }
    }
}

/// Options for a datagen run.
#[derive(Debug, Clone)]
pub struct DatagenOptions {
    /// Instructions per (µarch, benchmark) pair.
    pub instructions: u64,
    /// Feature-engineering hyperparameters.
    pub features: FeatureConfig,
    /// Workload seed.
    pub seed: u64,
    /// Chunking/sharding for the streaming writer.
    pub stream: StreamOptions,
    /// Pull the trace straight out of the simulators
    /// ([`SimPairSource`]) instead of materializing it first — the
    /// end-to-end O(chunk) path behind `tao datagen --stream`.
    pub from_generator: bool,
    /// Replay the functional side off a recorded trace file
    /// ([`TracePairSource`]) instead of re-simulating it — the path
    /// behind `tao datagen --from-trace`. Requires a single workload
    /// (a trace records exactly one benchmark) and implies the
    /// streaming writer.
    pub from_trace: Option<PathBuf>,
}

impl Default for DatagenOptions {
    fn default() -> Self {
        DatagenOptions {
            instructions: 20_000,
            features: FeatureConfig::default(),
            seed: 42,
            stream: StreamOptions::default(),
            from_generator: false,
            from_trace: None,
        }
    }
}

/// The in-memory form of one generated dataset.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    /// Opcode ids, one per instruction.
    pub opcodes: Vec<i32>,
    /// Flattened `[M, F]` feature matrix.
    pub features: Vec<f32>,
    /// Feature dimension `F`.
    pub feature_dim: usize,
    /// Flattened `[M, NUM_LABELS]` label matrix.
    pub labels: Vec<f32>,
    /// Ground-truth total cycles of the run.
    pub total_cycles: u64,
}

impl Dataset {
    /// Number of instructions `M`.
    pub fn len(&self) -> usize {
        self.opcodes.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.opcodes.is_empty()
    }
}

/// The `labels.npy` row for one sample (column order is part of the
/// on-disk format; the Python trainer indexes it positionally).
fn label_row(l: &Labels) -> [f32; NUM_LABELS] {
    [
        l.fetch_latency as f32,
        l.exec_latency as f32,
        l.branch_mispred as u8 as f32,
        l.access_level.index() as f32,
        l.icache_miss as u8 as f32,
        l.tlb_miss as u8 as f32,
    ]
}

/// Generate the aligned, adjusted trace for one (benchmark, µarch) pair.
pub fn adjusted_trace(
    workload: &Workload,
    uarch: &UarchConfig,
    instructions: u64,
    seed: u64,
) -> Result<AdjustedTrace> {
    let program = workload.build(seed);
    let functional = FunctionalSim::new(&program).run(instructions);
    let (detailed, _) = DetailedSim::new(&program, uarch).run(instructions);
    let adjusted = dataset::adjust(&detailed);
    dataset::align(&functional, adjusted)
}

/// Build the feature/label arrays from an adjusted trace, fully in
/// memory. The oracle for [`stream_dataset`] (which must reproduce it
/// byte for byte) and the convenient path for small traces.
pub fn featurize(adjusted: &AdjustedTrace, config: FeatureConfig) -> Dataset {
    let f = config.feature_dim();
    let m = adjusted.samples.len();
    let mut ds = Dataset {
        opcodes: Vec::with_capacity(m),
        features: vec![0.0; m * f],
        feature_dim: f,
        labels: Vec::with_capacity(m * NUM_LABELS),
        total_cycles: adjusted.total_cycles,
    };
    let mut fx = FeatureExtractor::new(config);
    for (i, s) in adjusted.samples.iter().enumerate() {
        // Zero-copy: the extractor writes the row straight into the
        // dataset matrix.
        let id = fx.extract_into(&s.func, &mut ds.features[i * f..(i + 1) * f]);
        ds.opcodes.push(id);
        ds.labels.extend_from_slice(&label_row(&s.labels));
    }
    ds
}

/// Generate and featurize in one step (in-memory path).
pub fn generate(
    workload: &Workload,
    uarch: &UarchConfig,
    opts: &DatagenOptions,
) -> Result<Dataset> {
    let adjusted = adjusted_trace(workload, uarch, opts.instructions, opts.seed)?;
    Ok(featurize(&adjusted, opts.features))
}

/// Write one in-memory dataset under `dir/<uarch>/<bench>/`.
pub fn write_dataset(dir: &Path, uarch: &str, bench: &str, ds: &Dataset) -> Result<()> {
    let d = dir.join(uarch).join(bench);
    std::fs::create_dir_all(&d).with_context(|| format!("mkdir {d:?}"))?;
    npy::write_i32_1d(&d.join("opcodes.npy"), &ds.opcodes)?;
    npy::write_f32_2d(&d.join("features.npy"), &ds.features, ds.len(), ds.feature_dim)?;
    npy::write_f32_2d(&d.join("labels.npy"), &ds.labels, ds.len(), NUM_LABELS)?;
    std::fs::write(
        d.join("total_cycles.txt"),
        format!("{}\n", ds.total_cycles),
    )?;
    Ok(())
}

// ---------------------------------------------------------------------
// Sharded streaming writer
// ---------------------------------------------------------------------

/// One shard's entry in `manifest.json`. Shard `index` covers global
/// rows `[start, start + rows)` and lives in `features_NNN.npy` /
/// `opcodes_NNN.npy` / `labels_NNN.npy` (see [`shard_file`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardEntry {
    /// Shard number (file-name suffix).
    pub index: usize,
    /// First global row covered.
    pub start: usize,
    /// Rows in the shard.
    pub rows: usize,
}

/// The sharded-dataset manifest: row/shape totals plus the shard table.
/// Written by [`stream_dataset`]; consumed lazily by [`merge_shards`] —
/// shard payloads are only ever streamed, never loaded whole.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    /// Total rows `M` across all shards.
    pub rows: usize,
    /// Feature dimension `F`.
    pub feature_dim: usize,
    /// Label columns (always [`NUM_LABELS`] today).
    pub num_labels: usize,
    /// Ground-truth total cycles of the run.
    pub total_cycles: u64,
    /// Shards in `index` order.
    pub shards: Vec<ShardEntry>,
}

/// Shard file name for one array stem, e.g. `features_002.npy`.
pub fn shard_file(stem: &str, index: usize) -> String {
    format!("{stem}_{index:03}.npy")
}

impl Manifest {
    /// Write `manifest.json` into `dir`.
    pub fn write(&self, dir: &Path) -> Result<()> {
        let entries: Vec<String> = self
            .shards
            .iter()
            .map(|e| {
                format!(
                    "    {{\"index\": {}, \"start\": {}, \"rows\": {}}}",
                    e.index, e.start, e.rows
                )
            })
            .collect();
        let mut f = std::fs::File::create(dir.join("manifest.json"))?;
        writeln!(
            f,
            "{{\n  \"rows\": {},\n  \"feature_dim\": {},\n  \"num_labels\": {},\n  \"total_cycles\": {},\n  \"shards\": [\n{}\n  ]\n}}",
            self.rows,
            self.feature_dim,
            self.num_labels,
            self.total_cycles,
            entries.join(",\n"),
        )?;
        Ok(())
    }

    /// Load `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| format!("read {path:?}"))?;
        let j = crate::util::json::Json::parse(&text)?;
        let field = |k: &str| {
            j.get(k)
                .and_then(|v| v.as_u64())
                .with_context(|| format!("manifest missing {k}"))
        };
        let shards = j
            .get("shards")
            .and_then(|v| v.as_arr())
            .context("manifest missing shards")?
            .iter()
            .map(|e| {
                let g = |k: &str| {
                    e.get(k)
                        .and_then(|v| v.as_u64())
                        .with_context(|| format!("shard entry missing {k}"))
                };
                Ok(ShardEntry {
                    index: g("index")? as usize,
                    start: g("start")? as usize,
                    rows: g("rows")? as usize,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(Manifest {
            rows: field("rows")? as usize,
            feature_dim: field("feature_dim")? as usize,
            num_labels: field("num_labels")? as usize,
            total_cycles: field("total_cycles")?,
            shards,
        })
    }
}

/// Counters from one [`stream_dataset`] run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamStats {
    /// Rows written across all shards.
    pub rows: usize,
    /// Chunks featurized.
    pub chunks: u64,
    /// Largest row count any chunk buffer ever held (≤ `chunk_size`).
    pub peak_chunk_rows: usize,
}

/// Stream one dataset to disk in bounded memory: per-chunk §4.1
/// alignment against `functional`, per-chunk featurization of
/// `samples`, sharded incremental `.npy` writes, and a `manifest.json`
/// describing the shards. Workers pull shards off an atomic-cursor
/// queue and warm their extractor to each shard start with the exact
/// [`FeatureExtractor::advance`] path, so the concatenated shards are
/// byte-identical to the in-memory [`featurize`] matrix no matter the
/// shard count or scheduling.
pub fn stream_dataset<S>(
    dir: &Path,
    functional: &S,
    samples: &[Sample],
    total_cycles: u64,
    config: FeatureConfig,
    stream: StreamOptions,
) -> Result<(Manifest, StreamStats)>
where
    S: RecordSource + Sync + ?Sized,
{
    let m = functional.len().min(samples.len());
    ensure!(
        m > 0,
        "cannot stream empty traces ({} functional, {} samples)",
        functional.len(),
        samples.len()
    );
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
    let chunk = stream.chunk_size.max(1);
    let per_shard = m.div_ceil(stream.shards.max(1));
    let shards_used = m.div_ceil(per_shard);
    let parallel = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let workers = shards_used.min(parallel).max(1);
    let f = config.feature_dim();

    let cursor = AtomicUsize::new(0);
    let results: Vec<Result<(Vec<ShardEntry>, StreamStats)>> = std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for _ in 0..workers {
            let cursor = &cursor;
            handles.push(scope.spawn(move || -> Result<(Vec<ShardEntry>, StreamStats)> {
                let mut fx = FeatureExtractor::new(config);
                // Instructions already folded into `fx` — the cursor
                // hands shards out in increasing order, so the gap from
                // here to the next shard start is replayed with the
                // cheap state-only path.
                let mut pos = 0usize;
                let mut entries = Vec::new();
                let mut stats = StreamStats::default();
                let mut feat_chunk: Vec<f32> = Vec::with_capacity(chunk * f);
                let mut op_chunk: Vec<i32> = Vec::with_capacity(chunk);
                let mut label_chunk: Vec<f32> = Vec::with_capacity(chunk * NUM_LABELS);
                loop {
                    let s = cursor.fetch_add(1, Ordering::Relaxed);
                    if s >= shards_used {
                        break;
                    }
                    let start = s * per_shard;
                    let end = (start + per_shard).min(m);
                    for smp in &samples[pos..start] {
                        fx.advance(&smp.func);
                    }
                    let mut feats_w = NpyWriter::create(
                        &dir.join(shard_file("features", s)),
                        Dtype::F32,
                        Some(f),
                    )?;
                    let mut ops_w = NpyWriter::create(
                        &dir.join(shard_file("opcodes", s)),
                        Dtype::I32,
                        None,
                    )?;
                    let mut labels_w = NpyWriter::create(
                        &dir.join(shard_file("labels", s)),
                        Dtype::F32,
                        Some(NUM_LABELS),
                    )?;
                    let mut done = start;
                    while done < end {
                        let cend = (done + chunk).min(end);
                        let rows = cend - done;
                        dataset::align_chunk(functional, &samples[done..cend], done)?;
                        feat_chunk.resize(rows * f, 0.0);
                        op_chunk.clear();
                        label_chunk.clear();
                        for (k, smp) in samples[done..cend].iter().enumerate() {
                            let row = &mut feat_chunk[k * f..(k + 1) * f];
                            op_chunk.push(fx.extract_into(&smp.func, row));
                            label_chunk.extend_from_slice(&label_row(&smp.labels));
                        }
                        feats_w.append_f32(&feat_chunk)?;
                        ops_w.append_i32(&op_chunk)?;
                        labels_w.append_f32(&label_chunk)?;
                        stats.chunks += 1;
                        stats.peak_chunk_rows = stats.peak_chunk_rows.max(rows);
                        done = cend;
                    }
                    pos = end;
                    let frows = feats_w.finalize()?;
                    let orows = ops_w.finalize()?;
                    let lrows = labels_w.finalize()?;
                    ensure!(
                        frows == end - start && orows == frows && lrows == frows,
                        "shard {s}: wrote {frows}/{orows}/{lrows} rows, expected {}",
                        end - start
                    );
                    entries.push(ShardEntry {
                        index: s,
                        start,
                        rows: frows,
                    });
                    stats.rows += frows;
                }
                Ok((entries, stats))
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("datagen worker panicked"))
            .collect()
    });

    let mut shards = Vec::new();
    let mut stats = StreamStats::default();
    for r in results {
        let (es, st) = r?;
        shards.extend(es);
        stats.rows += st.rows;
        stats.chunks += st.chunks;
        stats.peak_chunk_rows = stats.peak_chunk_rows.max(st.peak_chunk_rows);
    }
    shards.sort_by_key(|e| e.index);
    ensure!(stats.rows == m, "wrote {} rows, expected {m}", stats.rows);
    let manifest = Manifest {
        rows: m,
        feature_dim: f,
        num_labels: NUM_LABELS,
        total_cycles,
        shards,
    };
    manifest.write(dir)?;
    Ok((manifest, stats))
}

/// Reassemble a sharded dataset into the canonical single-file arrays
/// (`features.npy`, `opcodes.npy`, `labels.npy`) by streaming shard
/// payloads through a fixed-size copy buffer — the merge, like the
/// writers, holds O(1 MiB) regardless of dataset size, and the output
/// is byte-identical to what [`write_dataset`] produces for the same
/// data. With `remove_shards`, the shard files and manifest are deleted
/// after a successful merge.
pub fn merge_shards(dir: &Path, manifest: &Manifest, remove_shards: bool) -> Result<()> {
    merge_one(dir, manifest, "features", Dtype::F32, Some(manifest.feature_dim))?;
    merge_one(dir, manifest, "opcodes", Dtype::I32, None)?;
    merge_one(dir, manifest, "labels", Dtype::F32, Some(manifest.num_labels))?;
    if remove_shards {
        for e in &manifest.shards {
            for stem in ["features", "opcodes", "labels"] {
                std::fs::remove_file(dir.join(shard_file(stem, e.index)))
                    .with_context(|| format!("remove shard {stem}_{:03}", e.index))?;
            }
        }
        std::fs::remove_file(dir.join("manifest.json")).context("remove manifest.json")?;
    }
    Ok(())
}

fn merge_one(
    dir: &Path,
    manifest: &Manifest,
    stem: &str,
    dtype: Dtype,
    cols: Option<usize>,
) -> Result<()> {
    let out = dir.join(format!("{stem}.npy"));
    let mut w = NpyWriter::create(&out, dtype, cols)?;
    let mut buf = vec![0u8; 1 << 20];
    for e in &manifest.shards {
        let path = dir.join(shard_file(stem, e.index));
        let (d, shape, mut r) = npy::open_payload(&path)?;
        ensure!(d == dtype, "shard {path:?}: dtype {d:?}, expected {dtype:?}");
        ensure!(
            shape.first().copied() == Some(e.rows),
            "shard {path:?}: shape {shape:?} disagrees with manifest rows {}",
            e.rows
        );
        if let Some(c) = cols {
            ensure!(
                shape.get(1).copied() == Some(c),
                "shard {path:?}: shape {shape:?}, expected {c} columns"
            );
        }
        let mut remaining = shape.iter().product::<usize>() * dtype.size();
        while remaining > 0 {
            let n = remaining.min(buf.len());
            std::io::Read::read_exact(&mut r, &mut buf[..n])
                .with_context(|| format!("short read in {path:?}"))?;
            w.append_raw(&buf[..n])?;
            remaining -= n;
        }
    }
    let rows = w.finalize()?;
    ensure!(
        rows == manifest.rows,
        "merged {stem}: {rows} rows, manifest says {}",
        manifest.rows
    );
    Ok(())
}

// ---------------------------------------------------------------------
// Pull-based chunk sources (trace side of the streaming pipeline)
// ---------------------------------------------------------------------

/// Generator-backed [`ChunkSource`] for datagen: runs the functional
/// machine and the detailed simulator **in lockstep**, one committed
/// instruction at a time, and yields aligned (record, label-row)
/// chunks. This is the whole §4.1 workflow — adjust (fetch-clock deltas
/// over the retired-only stream) and align (per-record PC/opcode/
/// address cross-check) — streamed: no functional trace, no detailed
/// record vector and no sample vector ever exist. Ground-truth total
/// cycles are available from [`ChunkSource::total_cycles`] once the
/// stream is exhausted.
pub struct SimPairSource {
    functional: Machine,
    detailed: DetailedSim,
    remaining: u64,
    prev_fetch: u64,
    produced: usize,
    done: bool,
}

impl SimPairSource {
    /// Build the paired simulators for one (benchmark, µarch) run.
    pub fn new(
        workload: &Workload,
        uarch: &UarchConfig,
        instructions: u64,
        seed: u64,
    ) -> SimPairSource {
        let program = workload.build(seed);
        SimPairSource {
            functional: Machine::new(&program),
            detailed: DetailedSim::new(&program, uarch),
            remaining: instructions,
            prev_fetch: 0,
            produced: 0,
            done: false,
        }
    }

    /// Records yielded so far.
    pub fn produced(&self) -> usize {
        self.produced
    }
}

impl ChunkSource for SimPairSource {
    fn len_hint(&self) -> Option<usize> {
        // Upper bound: the program may halt before the budget runs out.
        Some(self.remaining as usize)
    }

    fn total_cycles(&self) -> Option<u64> {
        self.done.then(|| self.detailed.total_cycles())
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        buf.clear();
        let n = (max_rows as u64).min(self.remaining);
        for _ in 0..n {
            let Some(info) = self.detailed.step_commit(None) else {
                self.remaining = 0;
                break;
            };
            let Some(exec) = self.functional.step() else {
                bail!(
                    "functional stream halted before the detailed stream \
                     at instruction {}",
                    self.produced
                );
            };
            let f = exec.record;
            let d = &info.func;
            // The §4.1 alignment check, streamed record by record.
            ensure!(
                f.pc == d.pc && f.opcode == d.opcode && f.mem_addr == d.mem_addr,
                "trace mismatch at instruction {}: functional {:x}/{} vs detailed {:x}/{}",
                self.produced,
                f.pc,
                f.opcode,
                d.pc,
                d.opcode
            );
            let labels = Labels {
                fetch_latency: (info.fetch_clock - self.prev_fetch) as u32,
                exec_latency: (info.retire_clock - info.fetch_clock) as u32,
                branch_mispred: info.branch_mispred,
                access_level: info.access_level,
                icache_miss: info.icache_miss,
                tlb_miss: info.tlb_miss,
            };
            self.prev_fetch = info.fetch_clock;
            buf.cols.push(d);
            buf.labels.extend_from_slice(&label_row(&labels));
            self.produced += 1;
            self.remaining -= 1;
        }
        if self.remaining == 0 {
            self.done = true;
        }
        Ok(buf.len())
    }
}

/// Rows staged per pull from the recorded trace in
/// [`TracePairSource`] — the replay path's peak trace buffering.
const TRACE_STAGE_ROWS: usize = 8_192;

/// Replay variant of [`SimPairSource`]: the functional side comes off a
/// recorded on-disk trace (either format, via
/// [`open_trace_source`](crate::trace::open_trace_source)) while the
/// detailed simulator re-executes the program in lockstep. Every row is
/// cross-checked against the recorded PC/opcode/address — the §4.1
/// alignment guarantee still holds, now also guarding against a stale
/// or mismatched trace file (wrong benchmark, wrong seed). Peak trace
/// buffering is one staged chunk, independent of trace length.
pub struct TracePairSource {
    trace: Box<dyn crate::trace::TraceSource>,
    staged: ChunkBuf,
    staged_pos: usize,
    detailed: DetailedSim,
    remaining: u64,
    prev_fetch: u64,
    produced: usize,
    done: bool,
}

impl TracePairSource {
    /// Open `trace_path` and pair it with a fresh detailed simulation of
    /// `workload` built from `seed`. Fails typed if the file is not a
    /// tao trace, and early if it records a different benchmark.
    pub fn open(
        trace_path: &Path,
        workload: &Workload,
        uarch: &UarchConfig,
        instructions: u64,
        seed: u64,
    ) -> Result<TracePairSource> {
        let trace = crate::trace::open_trace_source(trace_path)?;
        ensure!(
            trace.name() == workload.name,
            "trace {trace_path:?} records benchmark {:?}, not {:?}",
            trace.name(),
            workload.name
        );
        let program = workload.build(seed);
        Ok(TracePairSource {
            trace,
            staged: ChunkBuf::new(),
            staged_pos: 0,
            detailed: DetailedSim::new(&program, uarch),
            remaining: instructions,
            prev_fetch: 0,
            produced: 0,
            done: false,
        })
    }

    /// Records yielded so far.
    pub fn produced(&self) -> usize {
        self.produced
    }
}

impl ChunkSource for TracePairSource {
    fn len_hint(&self) -> Option<usize> {
        // Upper bound: the trace (or the program) may end first.
        Some(self.remaining as usize)
    }

    fn total_cycles(&self) -> Option<u64> {
        self.done.then(|| self.detailed.total_cycles())
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        buf.clear();
        let n = (max_rows as u64).min(self.remaining);
        for _ in 0..n {
            if self.staged_pos == self.staged.cols.len() {
                // Decode the next trace chunk (v2 decompression happens
                // here, inside whatever thread is pulling this source).
                let pulled = self.trace.next_chunk(&mut self.staged, TRACE_STAGE_ROWS)?;
                self.staged_pos = 0;
                if pulled == 0 {
                    self.remaining = 0;
                    break;
                }
            }
            let Some(info) = self.detailed.step_commit(None) else {
                self.remaining = 0;
                break;
            };
            let i = self.staged_pos;
            let d = &info.func;
            // The §4.1 alignment check against the *recorded* stream.
            ensure!(
                self.staged.cols.pc[i] == d.pc
                    && self.staged.cols.opcode[i] == d.opcode.index() as u8
                    && self.staged.cols.mem_addr[i] == d.mem_addr,
                "trace mismatch at instruction {}: recorded {:x}/{} vs detailed {:x}/{} — \
                 was the trace written from the same benchmark and seed?",
                self.produced,
                self.staged.cols.pc[i],
                self.staged.cols.opcode[i],
                d.pc,
                d.opcode.index(),
            );
            let labels = Labels {
                fetch_latency: (info.fetch_clock - self.prev_fetch) as u32,
                exec_latency: (info.retire_clock - info.fetch_clock) as u32,
                branch_mispred: info.branch_mispred,
                access_level: info.access_level,
                icache_miss: info.icache_miss,
                tlb_miss: info.tlb_miss,
            };
            self.prev_fetch = info.fetch_clock;
            buf.cols.push(d);
            buf.labels.extend_from_slice(&label_row(&labels));
            self.staged_pos += 1;
            self.produced += 1;
            self.remaining -= 1;
        }
        if self.remaining == 0 {
            self.done = true;
        }
        Ok(buf.len())
    }
}

/// Trivial in-memory adapter: a resident [`RecordSource`] plus its
/// aligned samples as a [`ChunkSource`] — the byte-identity oracle for
/// the streaming writers. Alignment is re-verified chunk by chunk as it
/// pulls (the streaming equivalent of [`dataset::align`]).
pub struct PairedSliceSource<'a, S: RecordSource + ?Sized> {
    functional: &'a S,
    samples: &'a [Sample],
    total_cycles: u64,
    pos: usize,
    m: usize,
}

impl<'a, S: RecordSource + ?Sized> PairedSliceSource<'a, S> {
    /// Pair a functional source with its samples; yields
    /// `min(functional.len(), samples.len())` records.
    pub fn new(
        functional: &'a S,
        samples: &'a [Sample],
        total_cycles: u64,
    ) -> PairedSliceSource<'a, S> {
        let m = functional.len().min(samples.len());
        PairedSliceSource {
            functional,
            samples,
            total_cycles,
            pos: 0,
            m,
        }
    }
}

impl<S: RecordSource + ?Sized> ChunkSource for PairedSliceSource<'_, S> {
    fn len_hint(&self) -> Option<usize> {
        Some(self.m - self.pos)
    }

    fn total_cycles(&self) -> Option<u64> {
        Some(self.total_cycles)
    }

    fn next_chunk(&mut self, buf: &mut ChunkBuf, max_rows: usize) -> Result<usize> {
        ensure!(max_rows >= 1, "zero-length chunk request");
        buf.clear();
        let end = (self.pos + max_rows).min(self.m);
        dataset::align_chunk(self.functional, &self.samples[self.pos..end], self.pos)?;
        for s in &self.samples[self.pos..end] {
            buf.cols.push(&s.func);
            buf.labels.extend_from_slice(&label_row(&s.labels));
        }
        let n = end - self.pos;
        self.pos = end;
        Ok(n)
    }
}

/// One featurized chunk on its way to the shard-writer thread (a
/// rotating buffer set of the write pipeline).
#[derive(Default)]
struct FeatChunk {
    rows: usize,
    feats: Vec<f32>,
    ops: Vec<i32>,
    labels: Vec<f32>,
}

/// Commands through the write pipeline.
enum WriteCmd {
    /// Append the buffer's rows (splitting across shard boundaries).
    Append,
    /// Finalize the open shard and hand back the shard table.
    Finish,
}

/// The write pipeline: featurized chunks in, `.npy` appends out, shard
/// table back on [`WriteCmd::Finish`].
type WriterPipe = StagePipeline<FeatChunk, WriteCmd, Option<(Vec<ShardEntry>, usize)>>;

/// The shard-writer thread's state: the open shard's three incremental
/// writers plus the rotation bookkeeping (exactly the append loop the
/// stager used to run inline).
struct ShardSink {
    dir: PathBuf,
    per_shard: Option<usize>,
    f: usize,
    open: Option<ShardWriters>,
    shards: Vec<ShardEntry>,
    rows: usize,
}

impl ShardSink {
    /// Append one featurized chunk, splitting across shard-file
    /// boundaries on the same per-shard row grid as [`stream_dataset`].
    fn append(&mut self, c: &FeatChunk) -> Result<()> {
        let mut off = 0usize;
        while off < c.rows {
            if self.open.is_none() {
                self.open =
                    Some(ShardWriters::create(&self.dir, self.shards.len(), self.rows, self.f)?);
            }
            let w = self.open.as_mut().unwrap();
            let room = self.per_shard.map_or(c.rows - off, |p| (p - w.rows).min(c.rows - off));
            w.feats.append_f32(&c.feats[off * self.f..(off + room) * self.f])?;
            w.ops.append_i32(&c.ops[off..off + room])?;
            w.labels
                .append_f32(&c.labels[off * NUM_LABELS..(off + room) * NUM_LABELS])?;
            w.rows += room;
            self.rows += room;
            off += room;
            if Some(w.rows) == self.per_shard {
                let entry = self.open.take().unwrap().finalize(self.shards.len())?;
                self.shards.push(entry);
            }
        }
        Ok(())
    }

    fn finish(&mut self) -> Result<(Vec<ShardEntry>, usize)> {
        if let Some(w) = self.open.take() {
            let entry = w.finalize(self.shards.len())?;
            self.shards.push(entry);
        }
        Ok((std::mem::take(&mut self.shards), self.rows))
    }
}

/// A free featurized-chunk buffer, absorbing completed writes while
/// waiting (the write pipeline's rotation point).
fn writer_buffer(pipe: &mut WriterPipe) -> Result<FeatChunk> {
    if let Some(b) = pipe.take_buf() {
        return Ok(b);
    }
    match pipe.recv()? {
        PipeMsg::Done { buf, result, .. } => {
            result.map_err(|e| anyhow::anyhow!("shard writer: {e}"))?;
            Ok(buf)
        }
        PipeMsg::InitFailed { msg } => bail!("shard writer: {msg}"),
    }
}

/// Drain the write pipeline and return the shard table the
/// [`WriteCmd::Finish`] command produced.
fn drain_writer(pipe: &mut WriterPipe) -> Result<(Vec<ShardEntry>, usize)> {
    let mut table = None;
    while pipe.in_flight() > 0 {
        match pipe.recv()? {
            PipeMsg::Done { buf, result, .. } => {
                if let Some(t) = result.map_err(|e| anyhow::anyhow!("shard writer: {e}"))? {
                    table = Some(t);
                }
                pipe.release(buf);
            }
            PipeMsg::InitFailed { msg } => bail!("shard writer: {msg}"),
        }
    }
    table.context("shard writer returned no shard table")
}

/// Stream any label-carrying [`ChunkSource`] to a sharded on-disk
/// dataset in one sequential pass — **featurize-while-write**: this
/// thread pulls chunk k+1 and featurizes it into one rotating buffer
/// set while a writer thread (the engine's [`StagePipeline`], the same
/// double-buffering as the inference workers) appends chunk k through
/// the incremental [`NpyWriter`]s, rotating shard files on the same
/// per-shard row grid as [`stream_dataset`] (so shard files and
/// manifest are byte-identical whenever the source's length hint is
/// exact — appends run FIFO, so the bytes cannot reorder). Peak
/// buffering is O(chunk × F) for each of the two buffer sets,
/// regardless of stream length — with a generator-backed source the
/// trace itself never exists.
pub fn stream_dataset_source<C: ChunkSource + ?Sized>(
    dir: &Path,
    source: &mut C,
    config: FeatureConfig,
    stream: StreamOptions,
) -> Result<(Manifest, StreamStats)> {
    std::fs::create_dir_all(dir).with_context(|| format!("mkdir {dir:?}"))?;
    let chunk = stream.chunk_size.max(1);
    let f = config.feature_dim();
    // Shard grid from the length hint; sources with no hint write a
    // single shard (the merged output is identical either way).
    let per_shard = source
        .len_hint()
        .map(|m| m.div_ceil(stream.shards.max(1)).max(1));
    let mut fx = FeatureExtractor::new(config);
    let mut buf = ChunkBuf::new();
    let mut stats = StreamStats::default();
    let sink_dir = dir.to_path_buf();
    let mut pipe: WriterPipe =
        StagePipeline::spawn(vec![FeatChunk::default(), FeatChunk::default()], move || {
            let mut sink = ShardSink {
                dir: sink_dir,
                per_shard,
                f,
                open: None,
                shards: Vec::new(),
                rows: 0,
            };
            Ok(move |c: &FeatChunk, cmd: &WriteCmd| match cmd {
                WriteCmd::Append => sink.append(c).map(|()| None),
                WriteCmd::Finish => sink.finish().map(Some),
            })
        });
    loop {
        let n = source.next_chunk(&mut buf, chunk)?;
        if n == 0 {
            break;
        }
        ensure!(
            buf.labels.len() == n * NUM_LABELS,
            "chunk source carries no label channel ({} label values for {n} records)",
            buf.labels.len()
        );
        let mut fc = writer_buffer(&mut pipe)?;
        fc.rows = n;
        fc.feats.clear();
        fc.feats.resize(n * f, 0.0);
        fc.ops.clear();
        for i in 0..n {
            let rec = buf.cols.record(i);
            fc.ops.push(fx.extract_into(&rec, &mut fc.feats[i * f..(i + 1) * f]));
        }
        fc.labels.clear();
        fc.labels.extend_from_slice(&buf.labels);
        stats.chunks += 1;
        stats.peak_chunk_rows = stats.peak_chunk_rows.max(n);
        stats.rows += n;
        pipe.submit(fc, WriteCmd::Append)?;
    }
    ensure!(stats.rows > 0, "cannot stream an empty trace");
    let fc = writer_buffer(&mut pipe)?;
    pipe.submit(fc, WriteCmd::Finish)?;
    let (shards, written) = drain_writer(&mut pipe)?;
    pipe.shutdown();
    ensure!(
        written == stats.rows,
        "shard writer wrote {written} rows, expected {}",
        stats.rows
    );
    let total_cycles = source
        .total_cycles()
        .context("chunk source reported no total cycles after exhaustion")?;
    let manifest = Manifest {
        rows: stats.rows,
        feature_dim: f,
        num_labels: NUM_LABELS,
        total_cycles,
        shards,
    };
    manifest.write(dir)?;
    Ok((manifest, stats))
}

/// One open shard's three incremental array writers plus its row
/// bookkeeping (support for [`stream_dataset_source`]'s rotation).
struct ShardWriters {
    start: usize,
    rows: usize,
    feats: NpyWriter,
    ops: NpyWriter,
    labels: NpyWriter,
}

impl ShardWriters {
    fn create(dir: &Path, index: usize, start: usize, f: usize) -> Result<ShardWriters> {
        Ok(ShardWriters {
            start,
            rows: 0,
            feats: NpyWriter::create(&dir.join(shard_file("features", index)), Dtype::F32, Some(f))?,
            ops: NpyWriter::create(&dir.join(shard_file("opcodes", index)), Dtype::I32, None)?,
            labels: NpyWriter::create(
                &dir.join(shard_file("labels", index)),
                Dtype::F32,
                Some(NUM_LABELS),
            )?,
        })
    }

    fn finalize(self, index: usize) -> Result<ShardEntry> {
        let frows = self.feats.finalize()?;
        let orows = self.ops.finalize()?;
        let lrows = self.labels.finalize()?;
        ensure!(
            frows == self.rows && orows == frows && lrows == frows,
            "shard {index}: wrote {frows}/{orows}/{lrows} rows, expected {}",
            self.rows
        );
        Ok(ShardEntry {
            index,
            start: self.start,
            rows: frows,
        })
    }
}

/// Generator-backed end-to-end streaming datagen for one (benchmark,
/// µarch) pair: simulate → align → featurize → shard-write → merge with
/// O(chunk) peak buffering — no functional trace, no detailed trace, no
/// sample vector, no `[M, F]` matrix. Byte-identical outputs to
/// [`generate_streamed`] (and to the fully in-memory path) for the same
/// options.
pub fn generate_streamed_source(
    dir: &Path,
    workload: &Workload,
    uarch: &UarchConfig,
    opts: &DatagenOptions,
) -> Result<(Manifest, StreamStats)> {
    let mut source = SimPairSource::new(workload, uarch, opts.instructions, opts.seed);
    let d = dir.join(&uarch.name).join(workload.name);
    std::fs::create_dir_all(&d).with_context(|| format!("mkdir {d:?}"))?;
    let (manifest, stats) = stream_dataset_source(&d, &mut source, opts.features, opts.stream)?;
    merge_shards(&d, &manifest, !opts.stream.keep_shards)?;
    std::fs::write(
        d.join("total_cycles.txt"),
        format!("{}\n", manifest.total_cycles),
    )?;
    Ok((manifest, stats))
}

/// Trace-replay end-to-end streaming datagen for one (benchmark,
/// µarch) pair: the functional stream is decoded off `trace_path`
/// (either on-disk format) while the detailed simulator re-executes the
/// program in lockstep — same shape as [`generate_streamed_source`],
/// with the recorded trace standing in for the functional machine.
/// Byte-identical outputs to the generator paths when the trace was
/// recorded from the same (benchmark, seed, instructions) run.
pub fn generate_streamed_trace(
    dir: &Path,
    trace_path: &Path,
    workload: &Workload,
    uarch: &UarchConfig,
    opts: &DatagenOptions,
) -> Result<(Manifest, StreamStats)> {
    let mut source =
        TracePairSource::open(trace_path, workload, uarch, opts.instructions, opts.seed)?;
    let d = dir.join(&uarch.name).join(workload.name);
    std::fs::create_dir_all(&d).with_context(|| format!("mkdir {d:?}"))?;
    let (manifest, stats) = stream_dataset_source(&d, &mut source, opts.features, opts.stream)?;
    merge_shards(&d, &manifest, !opts.stream.keep_shards)?;
    std::fs::write(
        d.join("total_cycles.txt"),
        format!("{}\n", manifest.total_cycles),
    )?;
    Ok((manifest, stats))
}

/// Generate one (benchmark, µarch) dataset straight to disk: traces →
/// adjust → per-chunk align + featurize (sharded, bounded memory) →
/// merged canonical arrays. The full `[M, F]` matrix never exists in
/// memory. Returns the manifest and streaming counters.
pub fn generate_streamed(
    dir: &Path,
    workload: &Workload,
    uarch: &UarchConfig,
    opts: &DatagenOptions,
) -> Result<(Manifest, StreamStats)> {
    let program = workload.build(opts.seed);
    let functional = {
        let _sp = crate::stage_span!("functional");
        FunctionalSim::new(&program).run(opts.instructions)
    };
    let (detailed, _) = {
        let _sp = crate::stage_span!("detailed");
        DetailedSim::new(&program, uarch).run(opts.instructions)
    };
    let adjusted = dataset::adjust(&detailed);
    let d = dir.join(&uarch.name).join(workload.name);
    std::fs::create_dir_all(&d).with_context(|| format!("mkdir {d:?}"))?;
    let (manifest, stats) = {
        let _sp = crate::stage_span!("extract_write");
        stream_dataset(
            &d,
            &functional.records[..],
            &adjusted.samples,
            adjusted.total_cycles,
            opts.features,
            opts.stream,
        )?
    };
    {
        let _sp = crate::stage_span!("merge");
        merge_shards(&d, &manifest, !opts.stream.keep_shards)?;
    }
    std::fs::write(
        d.join("total_cycles.txt"),
        format!("{}\n", adjusted.total_cycles),
    )?;
    Ok((manifest, stats))
}

/// Write the run-level metadata JSON (feature config + opcode vocab).
pub fn write_meta(dir: &Path, opts: &DatagenOptions, uarchs: &[&UarchConfig]) -> Result<()> {
    let mut f = std::fs::File::create(dir.join("meta.json"))?;
    let vocab: Vec<String> = crate::features::opcode_vocabulary()
        .iter()
        .map(|(name, idx)| format!("    \"{name}\": {idx}"))
        .collect();
    let uarch_list: Vec<String> = uarchs
        .iter()
        .map(|u| format!("    \"{}\": \"{}\"", u.name, u.summary()))
        .collect();
    writeln!(
        f,
        "{{\n  \"instructions\": {},\n  \"seed\": {},\n  \"feature_config\": {{\"nb\": {}, \"nq\": {}, \"nm\": {}}},\n  \"feature_dim\": {},\n  \"num_labels\": {},\n  \"num_regs\": {},\n  \"opcode_vocab\": {{\n{}\n  }},\n  \"uarchs\": {{\n{}\n  }}\n}}",
        opts.instructions,
        opts.seed,
        opts.features.nb,
        opts.features.nq,
        opts.features.nm,
        opts.features.feature_dim(),
        NUM_LABELS,
        crate::isa::NUM_REGS,
        vocab.join(",\n"),
        uarch_list.join(",\n"),
    )?;
    Ok(())
}

/// Full datagen run: all benchmarks in `workloads` × all `uarchs`,
/// through the streaming sharded writer.
pub fn run(
    dir: &Path,
    workloads: &[Workload],
    uarchs: &[UarchConfig],
    opts: &DatagenOptions,
) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    let refs: Vec<&UarchConfig> = uarchs.iter().collect();
    write_meta(dir, opts, &refs)?;
    for uarch in uarchs {
        for w in workloads {
            let (manifest, stats) = if let Some(trace) = &opts.from_trace {
                generate_streamed_trace(dir, trace, w, uarch, opts)?
            } else if opts.from_generator {
                generate_streamed_source(dir, w, uarch, opts)?
            } else {
                generate_streamed(dir, w, uarch, opts)?
            };
            eprintln!(
                "datagen: {}/{} — {} insts, {} cycles (cpi {:.3}), {} shards x {} chunks",
                uarch.name,
                w.name,
                manifest.rows,
                manifest.total_cycles,
                manifest.total_cycles as f64 / manifest.rows.max(1) as f64,
                manifest.shards.len(),
                stats.chunks,
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    fn opts() -> DatagenOptions {
        DatagenOptions {
            instructions: 2_000,
            ..Default::default()
        }
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("tao-dg-{tag}-{}", std::process::id()))
    }

    #[test]
    fn generate_shapes_consistent() {
        let w = workloads::by_name("dee").unwrap();
        let ds = generate(&w, &UarchConfig::uarch_a(), &opts()).unwrap();
        assert_eq!(ds.len(), 2_000);
        assert_eq!(ds.features.len(), ds.len() * ds.feature_dim);
        assert_eq!(ds.labels.len(), ds.len() * NUM_LABELS);
        assert!(ds.total_cycles > 0);
    }

    #[test]
    fn labels_reconstruct_total_cycles() {
        let w = workloads::by_name("lee").unwrap();
        let ds = generate(&w, &UarchConfig::uarch_b(), &opts()).unwrap();
        let total = crate::dataset::reconstruct_cycles(
            ds.labels.chunks(NUM_LABELS).map(|l| l[0] as f64),
            ds.labels.chunks(NUM_LABELS).map(|l| l[1] as f64),
        );
        assert_eq!(total, ds.total_cycles);
    }

    #[test]
    fn write_and_read_back() {
        let dir = tmp("rb");
        let w = workloads::by_name("nab").unwrap();
        let ds = generate(&w, &UarchConfig::uarch_a(), &opts()).unwrap();
        write_dataset(&dir, "uarch_a", "nab", &ds).unwrap();
        let feats = npy::read(&dir.join("uarch_a/nab/features.npy")).unwrap();
        assert_eq!(feats.shape, vec![ds.len(), ds.feature_dim]);
        let ops = npy::read(&dir.join("uarch_a/nab/opcodes.npy")).unwrap();
        assert_eq!(ops.as_i32().unwrap(), ds.opcodes);
    }

    #[test]
    fn meta_json_is_parseable_shape() {
        let dir = tmp("meta");
        std::fs::create_dir_all(&dir).unwrap();
        let a = UarchConfig::uarch_a();
        write_meta(&dir, &opts(), &[&a]).unwrap();
        let s = std::fs::read_to_string(dir.join("meta.json")).unwrap();
        assert!(s.contains("\"feature_dim\""));
        assert!(s.contains("\"opcode_vocab\""));
        // Must at least be balanced braces.
        assert_eq!(s.matches('{').count(), s.matches('}').count());
    }

    #[test]
    fn different_uarchs_give_different_labels() {
        let w = workloads::by_name("mcf").unwrap();
        let a = generate(&w, &UarchConfig::uarch_a(), &opts()).unwrap();
        let c = generate(&w, &UarchConfig::uarch_c(), &opts()).unwrap();
        // Same inputs (µarch-agnostic)...
        assert_eq!(a.opcodes, c.opcodes);
        assert_eq!(a.features, c.features);
        // ...different labels (µarch-specific).
        assert_ne!(a.labels, c.labels);
    }

    #[test]
    fn streamed_run_byte_identical_to_in_memory() {
        // The full generate_streamed plumbing (sims included), multiple
        // shards, a chunk size that does not divide the shard size, and
        // cleanup of the shard files after the merge.
        let w = workloads::by_name("dee").unwrap();
        let uarch = UarchConfig::uarch_a();
        let mut o = opts();
        let ds = generate(&w, &uarch, &o).unwrap();
        let dir_mem = tmp("mem");
        write_dataset(&dir_mem, &uarch.name, w.name, &ds).unwrap();

        o.stream = StreamOptions {
            chunk_size: 257,
            shards: 3,
            keep_shards: false,
        };
        let dir_str = tmp("str");
        let (manifest, stats) = generate_streamed(&dir_str, &w, &uarch, &o).unwrap();
        assert_eq!(manifest.rows, 2_000);
        assert_eq!(manifest.shards.len(), 3);
        assert!(stats.peak_chunk_rows <= 257);
        assert!(stats.chunks >= 8, "2000 rows / 257-chunks: got {}", stats.chunks);

        let a = dir_mem.join("uarch_a/dee");
        let b = dir_str.join("uarch_a/dee");
        for name in ["features.npy", "opcodes.npy", "labels.npy", "total_cycles.txt"] {
            assert_eq!(
                std::fs::read(a.join(name)).unwrap(),
                std::fs::read(b.join(name)).unwrap(),
                "{name} differs between in-memory and streamed paths"
            );
        }
        // keep_shards=false removed the shard files and manifest.
        assert!(!b.join(shard_file("features", 0)).exists());
        assert!(!b.join("manifest.json").exists());
    }

    #[test]
    fn stream_keep_shards_manifest_round_trips() {
        let w = workloads::by_name("lee").unwrap();
        let uarch = UarchConfig::uarch_b();
        let adjusted = adjusted_trace(&w, &uarch, 1_000, 7).unwrap();
        let program = w.build(7);
        let functional = FunctionalSim::new(&program).run(1_000);
        let cfg = FeatureConfig {
            nb: 64,
            nq: 8,
            nm: 16,
        };
        let dir = tmp("keep");
        let (manifest, stats) = stream_dataset(
            &dir,
            &functional.records[..],
            &adjusted.samples,
            adjusted.total_cycles,
            cfg,
            StreamOptions {
                chunk_size: 64,
                shards: 4,
                keep_shards: true,
            },
        )
        .unwrap();
        assert_eq!(stats.rows, 1_000);
        assert_eq!(manifest.shards.iter().map(|e| e.rows).sum::<usize>(), 1_000);
        // Shard table is contiguous and ordered.
        let mut next = 0usize;
        for (i, e) in manifest.shards.iter().enumerate() {
            assert_eq!(e.index, i);
            assert_eq!(e.start, next);
            next += e.rows;
        }
        // The manifest round-trips through its JSON form.
        assert_eq!(Manifest::load(&dir).unwrap(), manifest);
        // Shard files survive alongside the merged arrays.
        merge_shards(&dir, &manifest, false).unwrap();
        assert!(dir.join(shard_file("features", 3)).exists());
        let merged = npy::read(&dir.join("features.npy")).unwrap();
        assert_eq!(merged.shape, vec![1_000, cfg.feature_dim()]);
    }

    #[test]
    fn paired_slice_source_matches_parallel_stream_writer() {
        // The sequential pull writer must produce the same shard files,
        // merged arrays and manifest as the parallel in-memory writer.
        let w = workloads::by_name("dee").unwrap();
        let uarch = UarchConfig::uarch_b();
        let adjusted = adjusted_trace(&w, &uarch, 1_500, 3).unwrap();
        let program = w.build(3);
        let functional = FunctionalSim::new(&program).run(1_500);
        let cfg = FeatureConfig {
            nb: 64,
            nq: 8,
            nm: 16,
        };
        let stream = StreamOptions {
            chunk_size: 129,
            shards: 4,
            keep_shards: true,
        };
        let dir_par = tmp("src-par");
        let (m_par, _) = stream_dataset(
            &dir_par,
            &functional.records[..],
            &adjusted.samples,
            adjusted.total_cycles,
            cfg,
            stream,
        )
        .unwrap();
        let dir_seq = tmp("src-seq");
        let mut source =
            PairedSliceSource::new(&functional.records[..], &adjusted.samples, adjusted.total_cycles);
        let (m_seq, stats) = stream_dataset_source(&dir_seq, &mut source, cfg, stream).unwrap();
        assert_eq!(m_seq, m_par);
        assert!(stats.peak_chunk_rows <= 129);
        for e in &m_seq.shards {
            for stem in ["features", "opcodes", "labels"] {
                let name = shard_file(stem, e.index);
                assert_eq!(
                    std::fs::read(dir_par.join(&name)).unwrap(),
                    std::fs::read(dir_seq.join(&name)).unwrap(),
                    "{name} differs between parallel and sequential writers"
                );
            }
        }
        merge_shards(&dir_par, &m_par, false).unwrap();
        merge_shards(&dir_seq, &m_seq, false).unwrap();
        for name in ["features.npy", "opcodes.npy", "labels.npy"] {
            assert_eq!(
                std::fs::read(dir_par.join(name)).unwrap(),
                std::fs::read(dir_seq.join(name)).unwrap()
            );
        }
    }

    #[test]
    fn generator_source_byte_identical_to_in_memory() {
        // End-to-end: simulators pulled through SimPairSource vs the
        // fully materialized generate() + write_dataset() path.
        let w = workloads::by_name("mcf").unwrap();
        let uarch = UarchConfig::uarch_a();
        let mut o = opts();
        o.stream = StreamOptions {
            chunk_size: 171,
            shards: 2,
            keep_shards: false,
        };
        let ds = generate(&w, &uarch, &o).unwrap();
        let dir_mem = tmp("gen-mem");
        write_dataset(&dir_mem, &uarch.name, w.name, &ds).unwrap();
        let dir_gen = tmp("gen-src");
        let (manifest, stats) = generate_streamed_source(&dir_gen, &w, &uarch, &o).unwrap();
        assert_eq!(manifest.rows, 2_000);
        assert_eq!(manifest.total_cycles, ds.total_cycles);
        assert!(stats.peak_chunk_rows <= 171);
        let a = dir_mem.join("uarch_a/mcf");
        let b = dir_gen.join("uarch_a/mcf");
        for name in ["features.npy", "opcodes.npy", "labels.npy", "total_cycles.txt"] {
            assert_eq!(
                std::fs::read(a.join(name)).unwrap(),
                std::fs::read(b.join(name)).unwrap(),
                "{name} differs between in-memory and generator-streamed paths"
            );
        }
        assert!(!b.join(shard_file("features", 0)).exists());
    }

    #[test]
    fn trace_replay_byte_identical_to_generator_path() {
        // Record a v2 trace, then datagen off it: outputs must match the
        // simulator-pulled streaming path byte for byte.
        let w = workloads::by_name("mcf").unwrap();
        let uarch = UarchConfig::uarch_a();
        let mut o = opts();
        o.stream = StreamOptions {
            chunk_size: 171,
            shards: 2,
            keep_shards: false,
        };
        let trace = tmp("replay").join("mcf.trace");
        std::fs::create_dir_all(trace.parent().unwrap()).unwrap();
        let program = w.build(o.seed);
        let cols = crate::functional::FunctionalSim::new(&program)
            .run(o.instructions)
            .to_columns();
        crate::trace::TraceWriteOptions::new(crate::trace::TraceFormat::V2)
            .chunk_rows(733)
            .write(&trace, w.name, &cols)
            .unwrap();

        let dir_gen = tmp("replay-gen");
        let (m_gen, _) = generate_streamed_source(&dir_gen, &w, &uarch, &o).unwrap();
        let dir_tr = tmp("replay-tr");
        let (m_tr, stats) = generate_streamed_trace(&dir_tr, &trace, &w, &uarch, &o).unwrap();
        assert_eq!(m_tr.rows, m_gen.rows);
        assert_eq!(m_tr.total_cycles, m_gen.total_cycles);
        assert!(stats.peak_chunk_rows <= 171);
        let a = dir_gen.join("uarch_a/mcf");
        let b = dir_tr.join("uarch_a/mcf");
        for name in ["features.npy", "opcodes.npy", "labels.npy", "total_cycles.txt"] {
            assert_eq!(
                std::fs::read(a.join(name)).unwrap(),
                std::fs::read(b.join(name)).unwrap(),
                "{name} differs between generator and trace-replay paths"
            );
        }

        // A mismatched trace (different benchmark) refuses early.
        let other = workloads::by_name("dee").unwrap();
        assert!(TracePairSource::open(&trace, &other, &uarch, 100, o.seed).is_err());
        // A tampered record trips the streamed §4.1 alignment check.
        let mut tampered = cols.clone();
        tampered.pc[100] ^= 0x1000;
        let bad_path = tmp("replay").join("mcf-bad.trace");
        crate::trace::TraceWriteOptions::new(crate::trace::TraceFormat::V2)
            .write(&bad_path, w.name, &tampered)
            .unwrap();
        let mut bad =
            TracePairSource::open(&bad_path, &w, &uarch, o.instructions, o.seed).unwrap();
        let mut buf = crate::trace::ChunkBuf::new();
        let mut failed = false;
        loop {
            match bad.next_chunk(&mut buf, 128) {
                Err(e) => {
                    assert!(
                        format!("{e:#}").contains("trace mismatch"),
                        "unexpected error: {e:#}"
                    );
                    failed = true;
                    break;
                }
                Ok(0) => break,
                Ok(_) => {}
            }
        }
        assert!(failed, "tampered replay should fail the alignment check");
    }

    #[test]
    fn sim_pair_source_reports_cycles_only_when_done() {
        let w = workloads::by_name("lee").unwrap();
        let mut src = SimPairSource::new(&w, &UarchConfig::uarch_a(), 300, 1);
        assert_eq!(src.total_cycles(), None);
        let mut buf = crate::trace::ChunkBuf::new();
        assert!(src.next_chunk(&mut buf, 0).is_err());
        while src.next_chunk(&mut buf, 100).unwrap() > 0 {
            assert_eq!(buf.labels.len(), buf.len() * NUM_LABELS);
        }
        assert_eq!(src.produced(), 300);
        let cycles = src.total_cycles().expect("cycles after exhaustion");
        let (det, _) = DetailedSim::new(&w.build(1), &UarchConfig::uarch_a()).run(300);
        assert_eq!(cycles, det.total_cycles);
    }

    #[test]
    fn label_free_source_rejected_by_stream_writer() {
        let w = workloads::by_name("dee").unwrap();
        let program = w.build(9);
        let functional = FunctionalSim::new(&program).run(500);
        let cols = functional.to_columns();
        // A bare trace source has no label channel: the dataset writer
        // must refuse it rather than write empty labels.
        let mut source = crate::trace::SliceChunkSource::new(&cols, None).unwrap();
        let err = stream_dataset_source(
            &tmp("nolabel"),
            &mut source,
            FeatureConfig::default(),
            StreamOptions::default(),
        );
        assert!(err.is_err());
    }

    #[test]
    fn stream_rejects_misaligned_chunk() {
        let w = workloads::by_name("nab").unwrap();
        let uarch = UarchConfig::uarch_a();
        let adjusted = adjusted_trace(&w, &uarch, 500, 42).unwrap();
        let program = w.build(42);
        let mut functional = FunctionalSim::new(&program).run(500);
        functional.records[300].pc ^= 0x40;
        let err = stream_dataset(
            &tmp("mis"),
            &functional.records[..],
            &adjusted.samples,
            adjusted.total_cycles,
            FeatureConfig::default(),
            StreamOptions::default(),
        );
        assert!(err.is_err(), "corrupted functional record must fail alignment");
    }

    #[test]
    fn single_shard_file_is_canonical_array() {
        // With one shard, the shard file itself is byte-identical to the
        // merged canonical array (same rows, same writer).
        let w = workloads::by_name("mcf").unwrap();
        let uarch = UarchConfig::uarch_c();
        let adjusted = adjusted_trace(&w, &uarch, 800, 1).unwrap();
        let program = w.build(1);
        let functional = FunctionalSim::new(&program).run(800);
        let dir = tmp("one");
        let (manifest, _) = stream_dataset(
            &dir,
            &functional.records[..],
            &adjusted.samples,
            adjusted.total_cycles,
            FeatureConfig::default(),
            StreamOptions {
                chunk_size: 100,
                shards: 1,
                keep_shards: true,
            },
        )
        .unwrap();
        merge_shards(&dir, &manifest, false).unwrap();
        assert_eq!(
            std::fs::read(dir.join(shard_file("features", 0))).unwrap(),
            std::fs::read(dir.join("features.npy")).unwrap()
        );
    }
}
