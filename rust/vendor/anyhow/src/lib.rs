//! Minimal, API-compatible subset of the `anyhow` crate.
//!
//! The workspace builds fully offline (no crates.io access), so the
//! handful of `anyhow` features the codebase uses are vendored here:
//! [`Error`], [`Result`], the [`Context`] extension trait, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Error values carry a context
//! chain; `{}` displays the outermost message and `{:#}` the full chain
//! (`outermost: ...: root`), matching upstream behaviour closely enough
//! for CLI error reporting.

use std::fmt;

/// Error type: a root cause plus a chain of context messages.
///
/// `chain[0]` is the root cause; later entries are contexts added on the
/// way up. Like upstream `anyhow::Error`, this type deliberately does
/// NOT implement `std::error::Error` — that is what allows the blanket
/// `From<E: std::error::Error>` conversion below to coexist with the
/// reflexive `From<Error> for Error`.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.push(context.to_string());
        self
    }

    /// The root cause message (innermost).
    pub fn root_cause(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, outermost first.
            for (i, msg) in self.chain.iter().rev().enumerate() {
                if i > 0 {
                    write!(f, ": ")?;
                }
                write!(f, "{msg}")?;
            }
            Ok(())
        } else {
            // `{}` — outermost message only.
            write!(f, "{}", self.chain.last().expect("non-empty chain"))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: message plus a Caused-by list.
        write!(f, "{}", self.chain.last().expect("non-empty chain"))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in self.chain.iter().rev().skip(1) {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` alias with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(|| ...)` to
/// `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error (or `None`) with a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Ok(v) => Ok(v),
            Err(e) => Err(e.into().context(f())),
        }
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(context)),
        }
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        match self {
            Some(v) => Ok(v),
            None => Err(Error::msg(f())),
        }
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn from_std_error_and_context_chain() {
        let e: Error = io_err().into();
        let e = e.context("opening trace").context("loading dataset");
        assert_eq!(format!("{e}"), "loading dataset");
        assert_eq!(
            format!("{e:#}"),
            "loading dataset: opening trace: no such file"
        );
        assert_eq!(e.root_cause(), "no such file");
    }

    #[test]
    fn result_and_option_context() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: no such file");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
        assert_eq!(Some(3).context("x").unwrap(), 3);
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(5).unwrap_err()), "five is right out");
        let e = anyhow!("code {}", 42);
        assert_eq!(format!("{e}"), "code 42");
    }

    #[test]
    fn error_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Error>();
    }
}
