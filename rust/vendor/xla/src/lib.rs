//! Offline stand-in for the `xla-rs` PJRT binding.
//!
//! The production build links the real PJRT CPU client and executes the
//! AOT-lowered HLO from `python/compile/aot.py`. This container has no
//! network access and no prebuilt libxla, so this crate vendors the small
//! slice of the `xla-rs` API surface the runtime uses
//! (`PjRtClient::cpu`, `HloModuleProto::from_text_file`,
//! `XlaComputation::from_proto`, `compile`, `execute`, `Literal`) behind
//! a **deterministic surrogate executor**:
//!
//! * "Compiling" records a seed hashed from the HLO text, so different
//!   artifacts produce different (but stable) predictions.
//! * "Executing" hashes each window of the staged inputs and maps the
//!   hash to plausible output ranges. Two inputs → the Tao tuple shape
//!   (fetch, exec, branch, access[4], icache, tlb); three inputs → the
//!   SimNet tuple shape (fetch, exec). Per-window outputs depend only on
//!   that window's bytes (plus the artifact seed), never on batch
//!   position — exactly the property the engine's overlap-aware batcher
//!   relies on and the equivalence tests assert.
//!
//! The engine, batcher, sharding, accumulation and reporting layers are
//! therefore fully exercisable (and benchmarkable) without Python or a
//! PJRT runtime; swap this path dependency for real xla-rs to run true
//! model inference. Keep the API here in lock-step with
//! `rust/src/runtime/artifact.rs`.

use std::fmt;

/// Error type mirroring `xla::Error` (display-only).
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

/// Result alias used across the binding.
pub type Result<T> = std::result::Result<T, Error>;

fn err(msg: impl Into<String>) -> Error {
    Error(msg.into())
}

// ---------------------------------------------------------------------
// Literals
// ---------------------------------------------------------------------

/// Element payload of a [`Literal`].
#[derive(Debug, Clone)]
pub enum Data {
    /// 32-bit floats.
    F32(Vec<f32>),
    /// 32-bit ints.
    I32(Vec<i32>),
    /// A tuple of literals (executable results).
    Tuple(Vec<Literal>),
}

/// Element types storable in a [`Literal`].
pub trait Element: Copy {
    /// Wrap a slice as literal data.
    fn wrap(v: &[Self]) -> Data;
    /// Unwrap literal data (None on dtype mismatch).
    fn unwrap(d: &Data) -> Option<Vec<Self>>;
}

impl Element for f32 {
    fn wrap(v: &[f32]) -> Data {
        Data::F32(v.to_vec())
    }
    fn unwrap(d: &Data) -> Option<Vec<f32>> {
        match d {
            Data::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl Element for i32 {
    fn wrap(v: &[i32]) -> Data {
        Data::I32(v.to_vec())
    }
    fn unwrap(d: &Data) -> Option<Vec<i32>> {
        match d {
            Data::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor: shape + data, mirroring `xla::Literal`.
#[derive(Debug, Clone)]
pub struct Literal {
    /// Dimensions (empty for scalars; as passed to [`Literal::reshape`]).
    shape: Vec<i64>,
    data: Data,
}

impl Literal {
    /// 1-D literal from a slice.
    pub fn vec1<T: Element>(v: &[T]) -> Literal {
        Literal {
            shape: vec![v.len() as i64],
            data: T::wrap(v),
        }
    }

    /// Tuple literal.
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            shape: vec![parts.len() as i64],
            data: Data::Tuple(parts),
        }
    }

    /// Number of scalar elements.
    pub fn element_count(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::Tuple(t) => t.len(),
        }
    }

    /// Reinterpret with a new shape (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if matches!(self.data, Data::Tuple(_)) {
            return Err(err("cannot reshape a tuple literal"));
        }
        if n as usize != self.element_count() {
            return Err(err(format!(
                "reshape {:?} -> {:?}: element count mismatch",
                self.shape, dims
            )));
        }
        Ok(Literal {
            shape: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    /// The shape.
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }

    /// Destructure a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.data {
            Data::Tuple(parts) => Ok(parts),
            _ => Err(err("not a tuple literal")),
        }
    }

    /// Copy out the elements as `T`.
    pub fn to_vec<T: Element>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.data).ok_or_else(|| err("literal dtype mismatch"))
    }
}

// ---------------------------------------------------------------------
// HLO + client + executable
// ---------------------------------------------------------------------

/// Parsed (here: raw) HLO module text.
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| err(format!("read hlo {path}: {e}")))?;
        Ok(HloModuleProto { text })
    }
}

/// A computation handle (the HLO carried through to compile).
pub struct XlaComputation {
    seed: u64,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            seed: fnv1a(proto.text.as_bytes(), 0xcbf2_9ce4_8422_2325),
        }
    }
}

/// The PJRT CPU client.
pub struct PjRtClient;

impl PjRtClient {
    /// Construct the CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// "Compile" a computation for this client.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable { seed: comp.seed })
    }
}

/// A device-resident result buffer.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Copy the buffer back to the host.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// A compiled executable (surrogate).
pub struct PjRtLoadedExecutable {
    seed: u64,
}

/// 64-bit FNV-1a over a byte slice, keyed by a starting state.
fn fnv1a(bytes: &[u8], mut h: u64) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Map a hash to [0, 1).
fn unit(h: u64) -> f32 {
    ((h >> 11) as f64 / (1u64 << 53) as f64) as f32
}

impl PjRtLoadedExecutable {
    /// Execute one batch. Inputs follow the artifact convention:
    /// `[opcodes [B,T], features [B,T,F]]` (Tao, 6 outputs) or
    /// `[opcodes, features, ctx [B,T,6]]` (SimNet, 2 outputs).
    ///
    /// Outputs are a single tuple buffer, per real PJRT tupled results:
    /// `result[0][0]` holds the tuple literal.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        if args.len() != 2 && args.len() != 3 {
            return Err(err(format!("surrogate expects 2 or 3 inputs, got {}", args.len())));
        }
        let ops = args[0].borrow();
        let feats = args[1].borrow();
        let fshape = feats.shape();
        if fshape.len() != 3 {
            return Err(err(format!("features must be [B,T,F], got {fshape:?}")));
        }
        let (b, t, f) = (fshape[0] as usize, fshape[1] as usize, fshape[2] as usize);
        if ops.element_count() != b * t {
            return Err(err("opcode/feature batch shape mismatch"));
        }
        let fvals = feats.to_vec::<f32>()?;
        let ovals = ops.to_vec::<i32>()?;

        let simnet = args.len() == 3;
        let mut fetch = Vec::with_capacity(b);
        let mut exec = Vec::with_capacity(b);
        let mut branch = Vec::with_capacity(b);
        let mut access = Vec::with_capacity(b * 4);
        let mut icache = Vec::with_capacity(b);
        let mut tlb = Vec::with_capacity(b);
        for w in 0..b {
            // Hash this window's bytes (features + opcodes), keyed by the
            // artifact seed. Position-independent by construction.
            let fbytes = unsafe {
                std::slice::from_raw_parts(
                    fvals[w * t * f..(w + 1) * t * f].as_ptr() as *const u8,
                    t * f * 4,
                )
            };
            let obytes = unsafe {
                std::slice::from_raw_parts(
                    ovals[w * t..(w + 1) * t].as_ptr() as *const u8,
                    t * 4,
                )
            };
            let h = fnv1a(obytes, fnv1a(fbytes, self.seed));
            // Plausible raw-model ranges; the runtime applies clamps,
            // sigmoids and softmax on top.
            fetch.push(1.0 + 4.0 * unit(h));
            exec.push(4.0 + 12.0 * unit(h.rotate_left(7)));
            if !simnet {
                branch.push(4.0 * (unit(h.rotate_left(13)) - 0.5));
                for k in 0..4u32 {
                    access.push(3.0 * (unit(h.rotate_left(17 + 5 * k)) - 0.5));
                }
                icache.push(4.0 * (unit(h.rotate_left(41)) - 0.5));
                tlb.push(4.0 * (unit(h.rotate_left(47)) - 0.5));
            }
        }

        let mut parts = vec![
            Literal::vec1(&fetch).reshape(&[b as i64])?,
            Literal::vec1(&exec).reshape(&[b as i64])?,
        ];
        if !simnet {
            parts.push(Literal::vec1(&branch).reshape(&[b as i64])?);
            parts.push(Literal::vec1(&access).reshape(&[b as i64, 4])?);
            parts.push(Literal::vec1(&icache).reshape(&[b as i64])?);
            parts.push(Literal::vec1(&tlb).reshape(&[b as i64])?);
        }
        Ok(vec![vec![PjRtBuffer {
            literal: Literal::tuple(parts),
        }]])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exe(seed_text: &str) -> PjRtLoadedExecutable {
        let proto = HloModuleProto {
            text: seed_text.to_string(),
        };
        PjRtClient::cpu()
            .unwrap()
            .compile(&XlaComputation::from_proto(&proto))
            .unwrap()
    }

    fn inputs(b: usize, t: usize, f: usize, fill: f32) -> (Literal, Literal) {
        let ops = Literal::vec1(&vec![7i32; b * t])
            .reshape(&[b as i64, t as i64])
            .unwrap();
        let feats = Literal::vec1(&vec![fill; b * t * f])
            .reshape(&[b as i64, t as i64, f as i64])
            .unwrap();
        (ops, feats)
    }

    #[test]
    fn literal_reshape_and_round_trip() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let l2 = l.reshape(&[2, 2]).unwrap();
        assert_eq!(l2.shape(), &[2, 2]);
        assert_eq!(l2.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn tao_shape_and_determinism() {
        let e = exe("HloModule tao");
        let (ops, feats) = inputs(4, 8, 5, 0.25);
        let r1 = e.execute::<Literal>(&[ops.clone(), feats.clone()]).unwrap();
        let parts = r1[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        assert_eq!(parts.len(), 6);
        assert_eq!(parts[0].to_vec::<f32>().unwrap().len(), 4);
        assert_eq!(parts[3].to_vec::<f32>().unwrap().len(), 16);
        let r2 = e.execute::<Literal>(&[ops, feats]).unwrap();
        let p2 = r2[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        assert_eq!(
            parts[0].to_vec::<f32>().unwrap(),
            p2[0].to_vec::<f32>().unwrap()
        );
    }

    #[test]
    fn simnet_shape() {
        let e = exe("HloModule simnet");
        let (ops, feats) = inputs(2, 4, 3, 0.5);
        let ctx = Literal::vec1(&vec![0.0f32; 2 * 4 * 6])
            .reshape(&[2, 4, 6])
            .unwrap();
        let r = e.execute::<Literal>(&[ops, feats, ctx]).unwrap();
        let parts = r[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn outputs_depend_on_window_bytes_not_position() {
        let e = exe("HloModule tao");
        // Batch of two identical windows -> identical outputs.
        let (ops, feats) = inputs(2, 4, 3, 0.75);
        let r = e.execute::<Literal>(&[ops, feats]).unwrap();
        let parts = r[0][0].to_literal_sync().unwrap().to_tuple().unwrap();
        let fetch = parts[0].to_vec::<f32>().unwrap();
        assert_eq!(fetch[0], fetch[1]);
        // Different artifact seed -> different outputs.
        let e2 = exe("HloModule other");
        let (ops, feats) = inputs(2, 4, 3, 0.75);
        let r2 = e2.execute::<Literal>(&[ops, feats]).unwrap();
        let f2 = r2[0][0].to_literal_sync().unwrap().to_tuple().unwrap()[0]
            .to_vec::<f32>()
            .unwrap();
        assert_ne!(fetch[0], f2[0]);
    }
}
