//! Router-tier integration tests: a consistent-hash router in front of
//! real workers (in-process `Server`s, or `tao serve` child processes
//! when a test needs to `kill -9` one), exercising the sharding
//! contract end to end:
//!
//! * jobs routed through the router are bit-identical to the offline
//!   engine, and land exactly where the hash ring predicts;
//! * `kill -9` on a worker mid-burst loses zero jobs — forwards fail
//!   over along the ring and the successor absorbs the keyspace;
//! * a local cache miss is served from the ring sibling's cache over
//!   `/v1/cache/lookup` (fleet-warm cache), bit-identically;
//! * a dead worker's cache journal warm-loads into its successor.
//!
//! Fault probes and the telemetry registry are process-global, so
//! every test holds `fault::exclusive()` like the serve suite.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::{Duration, Instant};

use tao_sim::runtime::ArtifactPool;
use tao_sim::serve::cli::write_surrogate_set;
use tao_sim::serve::http::{http_get, http_post};
use tao_sim::serve::loadgen::{
    artifact_key, assert_identical, offline_reference, predict_balance,
};
use tao_sim::serve::protocol::{artifacts_from_json, JobOutcome, JobSpec, ServeError};
use tao_sim::serve::ring::Member;
use tao_sim::serve::{HashRing, Router, RouterConfig, ServeConfig, Server, StatsSnapshot};
use tao_sim::telemetry::prometheus::{parse as parse_prom, sample_value};
use tao_sim::util::fault;
use tao_sim::util::json::Json;
use tao_sim::workloads::{mixed_scenarios, mixed_tenant_scenarios, ScenarioArtifact};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tao-router-test-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn worker_config() -> ServeConfig {
    ServeConfig {
        cache_entries: 512,
        admission_wait_ms: 100,
        ..ServeConfig::default()
    }
}

fn router_config(workers: &[String]) -> RouterConfig {
    RouterConfig {
        workers: workers.iter().map(|a| (a.clone(), 1)).collect(),
        health_interval_ms: 50,
        ..RouterConfig::default()
    }
}

fn to_spec(j: &tao_sim::workloads::ScenarioJob, chunk: usize) -> JobSpec {
    JobSpec {
        bench: j.bench.clone(),
        insts: j.insts,
        seed: j.seed,
        artifact: j.artifact.clone(),
        chunk,
        ctx_uarch: j.ctx_uarch.clone(),
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    }
}

/// Wait until the router's `/healthz` reports exactly `want` workers
/// live (the fleet is in the ring; measurements start failover-free).
fn wait_live(router_addr: &str, want: u64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        if let Ok(resp) = http_get(router_addr, "/healthz") {
            if let Ok(j) = Json::parse(&resp.body) {
                if j.get("workers_live").and_then(Json::as_u64) == Some(want) {
                    return;
                }
            }
        }
        assert!(
            Instant::now() < deadline,
            "router at {router_addr} never saw {want} live workers"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// Submit through the router, resubmitting on typed retryable answers
/// (what a well-behaved client does while the ring heals).
fn submit_retry(addr: &str, spec: &JobSpec) -> JobOutcome {
    let body = spec.to_json();
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let resp = http_post(addr, "/v1/simulate", &body).unwrap();
        if resp.status == 200 {
            return JobOutcome::from_json(&resp.body).unwrap();
        }
        let err = ServeError::from_body(resp.status, &resp.body);
        assert!(err.code.retryable(), "terminal failure via router: {err}");
        assert!(Instant::now() < deadline, "retries exhausted: {err}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

fn worker_stats(addr: &str) -> StatsSnapshot {
    let resp = http_get(addr, "/v1/stats").unwrap();
    assert_eq!(resp.status, 200);
    StatsSnapshot::from_json(&resp.body).unwrap()
}

/// The tentpole contract: a three-worker fleet behind the router. Every
/// job routed through the router is bit-identical to the offline
/// engine, the per-worker distribution equals the consistent-hash
/// prediction exactly, and the router's aggregated `/v1/stats` and
/// `/metrics` reconcile with the fleet.
#[test]
fn jobs_through_router_match_offline_and_follow_the_ring() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("ring-routing");
    let models = write_surrogate_set(&dir).unwrap();

    let mut worker_addrs = Vec::new();
    let mut worker_threads = Vec::new();
    for _ in 0..3 {
        let pool = ArtifactPool::load(&models).unwrap();
        let server = Server::bind(pool, &worker_config()).unwrap();
        worker_addrs.push(server.local_addr().unwrap().to_string());
        worker_threads.push(std::thread::spawn(move || server.run()));
    }
    let router = Router::bind(&router_config(&worker_addrs)).unwrap();
    let router_addr = router.local_addr().unwrap().to_string();
    let router_thread = std::thread::spawn(move || router.run());
    wait_live(&router_addr, 3);

    // Routing keys exactly as the router derives them: fingerprints
    // from the fleet's artifact listing.
    let arts_body = http_get(&router_addr, "/v1/artifacts").unwrap();
    assert_eq!(arts_body.status, 200, "router must relay /v1/artifacts");
    let infos = artifacts_from_json(&arts_body.body).unwrap();
    assert_eq!(infos.len(), 3);
    let keys: std::collections::HashMap<String, u64> = infos
        .iter()
        .map(|a| (a.name.clone(), artifact_key(&a.name, a.fingerprint)))
        .collect();

    let arts = vec![
        ScenarioArtifact { name: "serve_tao_a".into(), simnet: false },
        ScenarioArtifact { name: "serve_tao_b".into(), simnet: false },
        ScenarioArtifact { name: "serve_simnet_a".into(), simnet: true },
    ];
    let specs: Vec<JobSpec> =
        mixed_scenarios(&arts, 12, 150, 7).iter().map(|j| to_spec(j, 48)).collect();

    let before: Vec<StatsSnapshot> = worker_addrs.iter().map(|a| worker_stats(a)).collect();
    let outs: Vec<JobOutcome> = std::thread::scope(|scope| {
        let handles: Vec<_> = specs
            .iter()
            .map(|spec| {
                let addr = router_addr.clone();
                scope.spawn(move || submit_retry(&addr, spec))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for (spec, out) in specs.iter().zip(&outs) {
        let offline = offline_reference(spec, &dir).unwrap();
        assert_identical(&out.metrics, &offline, &format!("routed {spec:?}")).unwrap();
    }

    // Placement: measured per-worker deltas equal the hash prediction.
    let expected = predict_balance(&worker_addrs, &keys, specs.iter());
    for (addr, b) in worker_addrs.iter().zip(&before) {
        let served = worker_stats(addr).delta_from(b).jobs_done;
        assert_eq!(
            served, expected[addr],
            "worker {addr} served {served}, ring predicts {}",
            expected[addr]
        );
    }
    // One artifact's traffic never splits across workers.
    for art in &arts {
        let ring = HashRing::from_members(
            worker_addrs.iter().map(|a| Member { name: a.clone(), weight: 1 }),
        );
        assert!(ring.primary(keys[&art.name]).is_some());
    }

    // The router's aggregate stats cover the whole fleet.
    let resp = http_get(&router_addr, "/v1/stats").unwrap();
    assert_eq!(resp.status, 200);
    let agg = StatsSnapshot::from_json(&resp.body).unwrap();
    assert_eq!(agg.jobs_done, specs.len() as u64);
    let j = Json::parse(&resp.body).unwrap();
    assert_eq!(j.get("workers_polled").and_then(Json::as_u64), Some(3));

    // Router metrics: forwards counted per worker, no failovers on a
    // healthy fleet.
    let m = http_get(&router_addr, "/metrics").unwrap();
    assert_eq!(m.status, 200);
    let samples = parse_prom(&m.body).unwrap();
    let forwards = sample_value(&samples, "tao_router_forwards_total", &[]).unwrap_or(0.0);
    assert!(forwards >= specs.len() as f64, "forwards={forwards}");
    assert_eq!(
        sample_value(&samples, "tao_router_workers_live", &[]),
        Some(3.0)
    );

    assert_eq!(http_post(&router_addr, "/v1/shutdown", "").unwrap().status, 200);
    router_thread.join().unwrap().unwrap();
    for addr in &worker_addrs {
        assert_eq!(http_post(addr, "/v1/shutdown", "").unwrap().status, 200);
    }
    for t in worker_threads {
        t.join().unwrap().unwrap();
    }
}

/// The failover contract: `kill -9` one worker while a tenant-skewed
/// burst is in flight. Every job must end 200 (after typed retries at
/// worst), bit-identical to the offline engine; the dead worker's keys
/// land on its ring successor; the router counts the failovers.
#[test]
fn kill_minus_nine_mid_burst_loses_zero_jobs() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("failover");
    let models = write_surrogate_set(&dir).unwrap();
    let exe = env!("CARGO_BIN_EXE_tao");

    // Workers as real processes so SIGKILL is a real crash.
    let mut children = Vec::new();
    let mut worker_addrs = Vec::new();
    for i in 0..3 {
        let pf = dir.join(format!("worker-{i}.port"));
        let _ = std::fs::remove_file(&pf);
        let mut cmd = std::process::Command::new(exe);
        cmd.arg("serve");
        for m in &models {
            cmd.arg("--model").arg(m);
        }
        cmd.arg("--port")
            .arg("0")
            .arg("--port-file")
            .arg(&pf)
            .arg("--cache-entries")
            .arg("256")
            .stdout(std::process::Stdio::null())
            .stderr(std::process::Stdio::null());
        let child = cmd.spawn().unwrap();
        let deadline = Instant::now() + Duration::from_secs(30);
        let addr = loop {
            if let Ok(s) = std::fs::read_to_string(&pf) {
                if !s.trim().is_empty() {
                    break s.trim().to_string();
                }
            }
            assert!(Instant::now() < deadline, "worker {i} never wrote its port file");
            std::thread::sleep(Duration::from_millis(20));
        };
        children.push(child);
        worker_addrs.push(addr);
    }

    let router = Router::bind(&router_config(&worker_addrs)).unwrap();
    let router_addr = router.local_addr().unwrap().to_string();
    let handle = router.handle();
    let router_thread = std::thread::spawn(move || router.run());
    wait_live(&router_addr, 3);

    // Hot tenant = serve_tao_a: ~3/4 of the burst keys to one worker,
    // so killing that worker guarantees mid-burst failovers.
    let infos = {
        let resp = http_get(&router_addr, "/v1/artifacts").unwrap();
        artifacts_from_json(&resp.body).unwrap()
    };
    let hot_key = infos
        .iter()
        .find(|a| a.name == "serve_tao_a")
        .map(|a| artifact_key(&a.name, a.fingerprint))
        .unwrap();
    let ring = HashRing::from_members(
        worker_addrs.iter().map(|a| Member { name: a.clone(), weight: 1 }),
    );
    let walk = ring.replicas(hot_key, 2);
    let victim_addr = walk[0].to_string();
    let successor_addr = walk[1].to_string();
    let victim_idx = worker_addrs.iter().position(|a| *a == victim_addr).unwrap();

    let arts = vec![
        ScenarioArtifact { name: "serve_tao_a".into(), simnet: false },
        ScenarioArtifact { name: "serve_tao_b".into(), simnet: false },
        ScenarioArtifact { name: "serve_simnet_a".into(), simnet: true },
    ];
    let specs: Vec<JobSpec> = mixed_tenant_scenarios(&arts, 24, 30_000, 7, 0)
        .iter()
        .map(|j| to_spec(j, 1_024))
        .collect();

    let done = AtomicUsize::new(0);
    let cursor = AtomicUsize::new(0);
    let outs: Vec<JobOutcome> = std::thread::scope(|scope| {
        let results: std::sync::Mutex<Vec<Option<JobOutcome>>> =
            std::sync::Mutex::new(vec![None; specs.len()]);
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let (addr, specs, results, cursor, done) =
                    (&router_addr, &specs, &results, &cursor, &done);
                scope.spawn(move || loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= specs.len() {
                        break;
                    }
                    let out = submit_retry(addr, &specs[i]);
                    results.lock().unwrap()[i] = Some(out);
                    done.fetch_add(1, Ordering::Relaxed);
                })
            })
            .collect();
        // Mid-burst: once a few jobs have completed (and more are in
        // flight), SIGKILL the hot artifact's primary.
        let deadline = Instant::now() + Duration::from_secs(60);
        while done.load(Ordering::Relaxed) < 4 {
            assert!(Instant::now() < deadline, "burst never got going");
            std::thread::sleep(Duration::from_millis(5));
        }
        children[victim_idx].kill().unwrap();
        let _ = children[victim_idx].wait();
        for h in handles {
            h.join().unwrap();
        }
        results.into_inner().unwrap().into_iter().map(Option::unwrap).collect()
    });

    // Zero lost jobs, every result still exact.
    assert_eq!(outs.len(), specs.len());
    for (spec, out) in specs.iter().zip(&outs) {
        let offline = offline_reference(spec, &dir).unwrap();
        assert_identical(&out.metrics, &offline, &format!("failover {spec:?}")).unwrap();
    }

    // The keyspace moved: the ring successor served hot-tenant jobs
    // after the kill (its all-time count exceeds what it could have
    // served as a non-primary of the hot artifact alone).
    let successor_jobs = worker_stats(&successor_addr).jobs_done;
    assert!(successor_jobs > 0, "successor {successor_addr} served nothing");
    // The dead worker is out of the ring; the fleet reports degraded.
    wait_live(&router_addr, 2);
    let health = http_get(&router_addr, "/healthz").unwrap();
    assert_eq!(health.status, 200, "two live workers must still serve");
    assert!(health.body.contains("degraded"), "healthz: {}", health.body);

    // The router observed the crash: failovers (typed or transport)
    // were counted against the dead worker.
    let m = http_get(&router_addr, "/metrics").unwrap();
    let samples = parse_prom(&m.body).unwrap();
    let failovers = sample_value(&samples, "tao_router_failovers_total", &[]).unwrap_or(0.0);
    assert!(failovers > 0.0, "no failovers recorded after SIGKILL");

    handle.request_shutdown();
    router_thread.join().unwrap().unwrap();
    for mut c in children {
        let _ = c.kill();
        let _ = c.wait();
    }
}

/// Fleet-warm cache: worker B's local miss is answered from ring
/// sibling A's cache over `/v1/cache/lookup` — B executes zero model
/// batches and its result is bit-identical.
#[test]
fn local_miss_is_served_from_the_ring_siblings_cache() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("peer-cache");
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "pc", 8, 4).unwrap();

    let pool_a = ArtifactPool::load(std::slice::from_ref(&hlo)).unwrap();
    let server_a = Server::bind(pool_a, &worker_config()).unwrap();
    let addr_a = server_a.local_addr().unwrap().to_string();
    let thread_a = std::thread::spawn(move || server_a.run());

    let pool_b = ArtifactPool::load(std::slice::from_ref(&hlo)).unwrap();
    let cfg_b = ServeConfig {
        peers: vec![addr_a.clone()],
        peer_timeout_ms: 1_000,
        ..worker_config()
    };
    let server_b = Server::bind(pool_b, &cfg_b).unwrap();
    let addr_b = server_b.local_addr().unwrap().to_string();
    let thread_b = std::thread::spawn(move || server_b.run());

    let spec = JobSpec {
        bench: "mcf".into(),
        insts: 10_000,
        seed: 3,
        artifact: "pc".into(),
        chunk: 512,
        ctx_uarch: None,
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    };
    let chunks = spec.insts.div_ceil(spec.chunk as u64);

    // Cold on A: populates A's cache the normal way.
    let out_a = submit_retry(&addr_a, &spec);
    assert!(out_a.windows > 0, "cold run must execute");
    assert_eq!(out_a.cache_hits, 0);

    // Same job on B: every chunk misses locally, hits A's cache over
    // the wire, and skips execution entirely.
    let out_b = submit_retry(&addr_b, &spec);
    assert_eq!(out_b.cache_hits, chunks, "peer-warmed chunks must count as hits");
    assert_eq!(out_b.windows, 0, "peer-warmed job must not execute");
    assert_identical(&out_b.metrics, &out_a.metrics, "peer-cache result").unwrap();
    let offline = offline_reference(&spec, &dir).unwrap();
    assert_identical(&out_b.metrics, &offline, "peer-cache vs offline").unwrap();

    // B's stats attribute the warmth to the peer tier.
    let raw = http_get(&addr_b, "/v1/stats").unwrap().body;
    let j = Json::parse(&raw).unwrap();
    assert_eq!(
        j.get("cache_peer_hits").and_then(Json::as_u64),
        Some(chunks),
        "stats: {raw}"
    );
    // A served the lookups without counting them as its own traffic.
    let stats_a = worker_stats(&addr_a);
    assert_eq!(stats_a.jobs_done, 1, "peer lookups must not count as jobs on A");

    for addr in [&addr_a, &addr_b] {
        assert_eq!(http_post(addr, "/v1/shutdown", "").unwrap().status, 200);
    }
    thread_a.join().unwrap().unwrap();
    thread_b.join().unwrap().unwrap();
}

/// A dead worker's `--cache-journal` file warm-loads read-only into
/// its ring successor: the successor serves the dead worker's keyspace
/// hot from the first request, and the journal file is not modified.
#[test]
fn dead_workers_journal_warm_loads_into_successor() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("warm-journal");
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "wj", 8, 4).unwrap();
    let journal = dir.join("victim.tjr");
    let _ = std::fs::remove_file(&journal);

    let spec = JobSpec {
        bench: "xal".into(),
        insts: 8_000,
        seed: 5,
        artifact: "wj".into(),
        chunk: 256,
        ctx_uarch: None,
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    };
    let chunks = spec.insts.div_ceil(spec.chunk as u64);

    // The "victim": journaled worker, runs the job, drains cleanly.
    // (The journal is equally replayable after a crash — that recovery
    // path is pinned by the serve suite; here the subject is the
    // cross-worker warm-load.)
    let pool = ArtifactPool::load(std::slice::from_ref(&hlo)).unwrap();
    let cfg = ServeConfig { cache_journal: Some(journal.clone()), ..worker_config() };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let t = std::thread::spawn(move || server.run());
    let cold = submit_retry(&addr, &spec);
    assert!(cold.windows > 0);
    assert_eq!(http_post(&addr, "/v1/shutdown", "").unwrap().status, 200);
    t.join().unwrap().unwrap();
    let journal_bytes = std::fs::read(&journal).unwrap();
    assert!(!journal_bytes.is_empty());

    // The "successor": fresh worker, no journal of its own, warm-loads
    // the victim's file read-only.
    let pool = ArtifactPool::load(std::slice::from_ref(&hlo)).unwrap();
    let cfg = ServeConfig { warm_journals: vec![journal.clone()], ..worker_config() };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let t = std::thread::spawn(move || server.run());
    let warm = submit_retry(&addr, &spec);
    assert_eq!(warm.cache_hits, chunks, "successor must serve the keyspace hot");
    assert_eq!(warm.windows, 0, "successor must not re-execute");
    assert_identical(&warm.metrics, &cold.metrics, "warm-load result").unwrap();
    assert_eq!(http_post(&addr, "/v1/shutdown", "").unwrap().status, 200);
    let final_stats = t.join().unwrap().unwrap();
    assert_eq!(final_stats.cache_recovered, chunks);

    // Read-only: the dead worker's journal is byte-identical.
    assert_eq!(std::fs::read(&journal).unwrap(), journal_bytes, "journal was modified");
}

/// Per-artifact quotas: with `cache_quotas` capping one artifact at a
/// sliver, the hot tenant churns its own slice while the cold tenant's
/// working set survives verbatim — and the per-artifact stats say so.
#[test]
fn cache_quota_protects_the_cold_tenant_from_a_hot_one() {
    use tao_sim::serve::cache::ENTRY_BYTES;

    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("quota");
    let models = vec![
        tao_sim::runtime::write_surrogate_artifact(&dir, "hot", 8, 4).unwrap(),
        tao_sim::runtime::write_surrogate_artifact(&dir, "cold", 8, 4).unwrap(),
    ];
    let pool = ArtifactPool::load(&models).unwrap();
    // Hot tenant: 8 entries' worth of bytes. Cold tenant: the implicit
    // proportional split (256 entries), far more than its job needs.
    let cfg = ServeConfig {
        cache_quotas: vec![("hot".into(), 8 * ENTRY_BYTES)],
        ..worker_config()
    };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let t = std::thread::spawn(move || server.run());

    let spec = |artifact: &str, seed: u64, insts: u64| JobSpec {
        bench: "mcf".into(),
        insts,
        seed,
        artifact: artifact.into(),
        chunk: 256,
        ctx_uarch: None,
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    };
    // Cold tenant caches its working set (20 chunks).
    let cold_spec = spec("cold", 1, 5_120);
    let cold_first = submit_retry(&addr, &cold_spec);
    assert!(cold_first.windows > 0);
    // Hot tenant churns 40 distinct chunks through an 8-entry quota.
    let hot = submit_retry(&addr, &spec("hot", 2, 10_240));
    assert!(hot.windows > 0);
    // The cold tenant replays entirely from cache: the hot churn could
    // not evict it.
    let cold_again = submit_retry(&addr, &cold_spec);
    assert_eq!(cold_again.windows, 0, "hot tenant evicted the cold tenant");
    assert_eq!(cold_again.cache_hits, 20);
    assert_identical(&cold_again.metrics, &cold_first.metrics, "quota replay").unwrap();

    // Per-artifact accounting on the wire: hot capped at its quota
    // with evictions, cold intact with zero evictions.
    let raw = http_get(&addr, "/v1/stats").unwrap().body;
    let j = Json::parse(&raw).unwrap();
    let arts = j.get("cache_artifacts").expect("cache_artifacts object");
    let hot_stats = arts.get("hot").expect("hot artifact stats");
    let cold_stats = arts.get("cold").expect("cold artifact stats");
    assert_eq!(hot_stats.req_u64("quota_entries").unwrap(), 8);
    assert_eq!(hot_stats.req_u64("entries").unwrap(), 8);
    assert!(hot_stats.req_u64("evictions").unwrap() >= 32);
    assert_eq!(cold_stats.req_u64("entries").unwrap(), 20);
    assert_eq!(cold_stats.req_u64("evictions").unwrap(), 0);
    assert_eq!(cold_stats.req_u64("hits").unwrap(), 20);

    assert_eq!(http_post(&addr, "/v1/shutdown", "").unwrap().status, 200);
    t.join().unwrap().unwrap();
}
