//! Cross-module integration tests: the full trace → dataset → features
//! pipeline, simulator cross-validation, randomized program properties,
//! streaming sharded datagen vs the in-memory oracle, and (when
//! `make artifacts` has run) the PJRT end-to-end path.

use tao_sim::datagen::{self, DatagenOptions, StreamOptions};
use tao_sim::dataset::{self, AdjustedTrace, Labels, Sample};
use tao_sim::detailed::DetailedSim;
use tao_sim::features::{FeatureConfig, FeatureExtractor};
use tao_sim::functional::FunctionalSim;
use tao_sim::isa::{Condition, Instruction, Opcode, Program, Reg};
use tao_sim::trace::{AccessLevel, FuncRecord, FunctionalTrace};
use tao_sim::uarch::UarchConfig;
use tao_sim::util::Rng;
use tao_sim::workloads;

/// The §4.1 pipeline end to end, every benchmark, every preset µarch:
/// traces align, totals are preserved, features have the right shape.
#[test]
fn dataset_pipeline_all_benchmarks_all_uarchs() {
    let insts = 3_000;
    for uarch in [UarchConfig::uarch_a(), UarchConfig::uarch_c()] {
        for w in workloads::suite() {
            let program = w.build(11);
            let functional = FunctionalSim::new(&program).run(insts);
            let (detailed, stats) = DetailedSim::new(&program, &uarch).run(insts);
            assert_eq!(stats.instructions, insts);
            let adjusted = dataset::adjust(&detailed);
            let aligned = dataset::align(&functional, adjusted)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", uarch.name, w.name));
            assert_eq!(aligned.samples.len(), insts as usize);
            assert_eq!(
                aligned.reconstructed_cycles(),
                detailed.total_cycles,
                "{}/{}: Figure 2 invariant",
                uarch.name,
                w.name
            );
        }
    }
}

/// Functional and detailed simulators must commit identical streams for
/// *randomly generated* programs, not just the curated suite.
#[test]
fn property_random_programs_commit_identical_streams() {
    let mut rng = Rng::new(0xF00D);
    for trial in 0..25 {
        let len = 40 + rng.index(60);
        let program = random_program(&mut rng, len);
        if program.validate().is_err() {
            continue;
        }
        let n = 1_500;
        let functional = FunctionalSim::new(&program).run(n);
        let (detailed, _) = DetailedSim::new(&program, &UarchConfig::uarch_b()).run(n);
        let committed: Vec<_> = detailed.retired().map(|r| r.func).collect();
        assert_eq!(
            committed.len(),
            functional.records.len(),
            "trial {trial}: lengths differ"
        );
        for (i, (a, b)) in committed.iter().zip(&functional.records).enumerate() {
            assert_eq!(a, b, "trial {trial}: record {i} differs");
        }
    }
}

/// Random straight-line-plus-loops program generator for property tests.
fn random_program(rng: &mut Rng, len: usize) -> Program {
    let mut insts = Vec::with_capacity(len + 8);
    // Prologue: seed a few registers.
    for r in 1..6u8 {
        insts.push(
            Instruction::new(Opcode::Movi)
                .dst(Reg::x(r))
                .imm(rng.gen_range(1_000) as i64 + 1),
        );
    }
    let body_start = insts.len();
    for _ in 0..len {
        let pick = rng.index(10);
        let inst = match pick {
            0..=3 => {
                let ops = [Opcode::Add, Opcode::Sub, Opcode::Eor, Opcode::Orr, Opcode::Mul];
                Instruction::new(ops[rng.index(ops.len())])
                    .dst(Reg::x(1 + rng.index(8) as u8))
                    .src1(Reg::x(1 + rng.index(8) as u8))
                    .imm(rng.gen_range(64) as i64)
            }
            4..=5 => Instruction::new(Opcode::Ldr)
                .dst(Reg::x(1 + rng.index(8) as u8))
                .src1(Reg::x(1 + rng.index(8) as u8))
                .imm(rng.gen_range(512) as i64),
            6 => Instruction::new(Opcode::Str)
                .src1(Reg::x(1 + rng.index(8) as u8))
                .imm(rng.gen_range(512) as i64)
                .src3(Reg::x(1 + rng.index(8) as u8)),
            7 => {
                // Forward conditional skip (target patched below).
                Instruction::new(Opcode::Bcond)
                    .src1(Reg::x(1 + rng.index(8) as u8))
                    .imm(rng.gen_range(500) as i64)
                    .cond(Condition::Gt)
                    .target(usize::MAX)
            }
            _ => Instruction::new(Opcode::Nop),
        };
        insts.push(inst);
    }
    // Patch forward branches to valid targets.
    let end = insts.len();
    for i in body_start..end {
        if insts[i].target == Some(usize::MAX) {
            insts[i].target = Some((i + 1 + rng.index(4)).min(end));
        }
    }
    // Outer loop: x9 counts down from large; repeat body.
    insts.push(
        Instruction::new(Opcode::Subs)
            .dst(Reg::x(9))
            .src1(Reg::x(9))
            .imm(-1), // increments forever; cbnz below keeps looping
    );
    insts.push(Instruction::new(Opcode::Cbnz).src1(Reg::x(9)).target(body_start));
    Program {
        name: "random".into(),
        insts,
        data_size: 4096,
        init_words: vec![(0, 7), (8, 99)],
        init_regs: vec![(Reg::x(9), 1)],
    }
}

/// Feature extraction over real traces: deterministic, right shape, no
/// NaNs, and identical between the datagen path and a fresh extractor.
#[test]
fn feature_extraction_consistent_with_datagen() {
    let w = workloads::by_name("xal").unwrap();
    let uarch = UarchConfig::uarch_a();
    let opts = DatagenOptions {
        instructions: 2_000,
        ..Default::default()
    };
    let ds = datagen::generate(&w, &uarch, &opts).unwrap();
    // Recompute manually.
    let program = w.build(opts.seed);
    let functional = FunctionalSim::new(&program).run(opts.instructions);
    let cfg = FeatureConfig::default();
    let mut fx = FeatureExtractor::new(cfg);
    let mut row = vec![0.0f32; cfg.feature_dim()];
    for (i, rec) in functional.records.iter().enumerate() {
        let id = fx.extract_into(rec, &mut row);
        assert_eq!(id, ds.opcodes[i], "opcode id at {i}");
        let stored = &ds.features[i * cfg.feature_dim()..(i + 1) * cfg.feature_dim()];
        assert_eq!(stored, &row[..], "feature row {i}");
        assert!(row.iter().all(|v| v.is_finite()), "non-finite feature at {i}");
    }
}

/// Labels across microarchitectures: inputs identical, labels reflect the
/// design (µArch C strictly outperforms µArch A overall).
#[test]
fn labels_reflect_microarchitecture() {
    let w = workloads::by_name("dee").unwrap();
    let opts = DatagenOptions {
        instructions: 5_000,
        ..Default::default()
    };
    let a = datagen::generate(&w, &UarchConfig::uarch_a(), &opts).unwrap();
    let c = datagen::generate(&w, &UarchConfig::uarch_c(), &opts).unwrap();
    assert_eq!(a.features, c.features);
    assert!(a.total_cycles > c.total_cycles, "A should be slower than C");
}

/// Acceptance gate for the overlap-aware batcher: on a ≥100k-instruction
/// synthetic trace, the rolling-buffer batcher must stage byte-identical
/// batches to the seed's per-window ring copy, flush for flush
/// (the shared driver also asserts flush counts and partial flushes).
#[test]
fn overlap_batcher_byte_identical_to_naive_at_100k() {
    tao_sim::coordinator::engine::check_batcher_equivalence(32, 16, 128, 100_000, 0x0B17);
}

/// The SoA pipeline end to end: functional trace -> columns -> columnar
/// file round trip -> feature extraction parity with the AoS path.
#[test]
fn columnar_trace_pipeline_matches_aos() {
    let dir = std::env::temp_dir().join(format!("tao-cols-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let program = workloads::by_name("mcf").unwrap().build(9);
    let trace = FunctionalSim::new(&program).run(5_000);
    let cols = trace.to_columns();

    // Columnar serialization round trip, interoperable with the AoS
    // reader/writer.
    let path = dir.join("mcf.cols.trace");
    tao_sim::trace::write_functional_columns(&path, &trace.name, &cols).unwrap();
    let (name, cols2) = tao_sim::trace::read_functional_columns(&path).unwrap();
    assert_eq!(name, trace.name);
    assert_eq!(cols2, cols);
    assert_eq!(tao_sim::trace::read_functional(&path).unwrap(), trace);

    // Feature extraction over assembled columnar records matches AoS.
    let cfg = FeatureConfig::default();
    let mut fx_aos = FeatureExtractor::new(cfg);
    let mut fx_soa = FeatureExtractor::new(cfg);
    let mut row_a = vec![0.0f32; cfg.feature_dim()];
    let mut row_s = vec![0.0f32; cfg.feature_dim()];
    for (i, rec) in trace.records.iter().enumerate() {
        let ida = fx_aos.extract_into(rec, &mut row_a);
        let ids = fx_soa.extract_into(&cols.record(i), &mut row_s);
        assert_eq!(ida, ids, "opcode id at {i}");
        assert_eq!(row_a, row_s, "feature row {i}");
    }
}

/// Synthetic functional trace + matching adjusted trace, no simulators:
/// random opcode mix (branches and memory ops exercise every extractor
/// history structure), random-but-consistent labels.
fn synthetic_pair(n: usize, seed: u64) -> (FunctionalTrace, AdjustedTrace) {
    let mut rng = Rng::new(seed);
    let mut records = Vec::with_capacity(n);
    let mut samples = Vec::with_capacity(n);
    let mut clock = 0u64;
    let mut last_retire = 0u64;
    for i in 0..n {
        let opcode = match rng.index(10) {
            0..=3 => Opcode::Add,
            4..=5 => Opcode::Ldr,
            6 => Opcode::Str,
            7..=8 => Opcode::Bcond,
            _ => Opcode::Mul,
        };
        let is_mem = matches!(opcode, Opcode::Ldr | Opcode::Str);
        let rec = FuncRecord {
            pc: 0x400000 + (i as u64 % 4096) * 4,
            opcode,
            reg_bitmap: 1 + rng.index(255) as u64,
            mem_addr: if is_mem { 0x10000 + rng.index(1 << 16) as u64 } else { 0 },
            mem_bytes: if is_mem { 8 } else { 0 },
            taken: rng.chance(0.5),
        };
        records.push(rec);
        let fetch = 1 + rng.gen_range(3) as u32;
        let exec = 1 + rng.gen_range(20) as u32;
        clock += fetch as u64;
        last_retire = clock + exec as u64;
        samples.push(Sample {
            func: rec,
            labels: Labels {
                fetch_latency: fetch,
                exec_latency: exec,
                branch_mispred: opcode == Opcode::Bcond && rng.chance(0.1),
                access_level: if is_mem { AccessLevel::L1 } else { AccessLevel::None },
                icache_miss: rng.chance(0.01),
                tlb_miss: rng.chance(0.005),
            },
        });
    }
    let functional = FunctionalTrace {
        name: "synthetic".into(),
        records,
    };
    let adjusted = AdjustedTrace {
        name: "synthetic".into(),
        uarch: "synthetic".into(),
        samples,
        total_cycles: last_retire,
    };
    (functional, adjusted)
}

/// Streaming acceptance gate, single shard: a ~50k-row synthetic trace
/// streamed in 4k-row chunks (the trace is >10x larger than any chunk
/// buffer) must produce byte-identical `.npy` files to the seed's
/// in-memory featurize-then-write path.
#[test]
fn streaming_datagen_single_shard_byte_identical_at_50k() {
    let n = 50_000;
    let (functional, adjusted) = synthetic_pair(n, 0x5EED_DA7A);
    let cfg = FeatureConfig {
        nb: 128,
        nq: 16,
        nm: 32,
    };
    let root = std::env::temp_dir().join(format!("tao-int-dg1-{}", std::process::id()));

    // In-memory oracle.
    let aligned = dataset::align(&functional, adjusted.clone()).unwrap();
    assert_eq!(aligned.samples.len(), n);
    let ds = datagen::featurize(&aligned, cfg);
    datagen::write_dataset(&root, "mem", "syn", &ds).unwrap();

    // Streamed, one shard, 4k chunks.
    let chunk = 4_096;
    let out = root.join("stream");
    let (manifest, stats) = datagen::stream_dataset(
        &out,
        &functional.records[..],
        &adjusted.samples,
        adjusted.total_cycles,
        cfg,
        StreamOptions {
            chunk_size: chunk,
            shards: 1,
            keep_shards: true,
        },
    )
    .unwrap();
    datagen::merge_shards(&out, &manifest, false).unwrap();

    // Peak buffering really was bounded by the chunk size.
    assert!(stats.peak_chunk_rows <= chunk);
    assert_eq!(stats.chunks, (n as u64).div_ceil(chunk as u64));
    assert!(n >= 10 * chunk, "trace must dwarf the chunk buffer");

    let mem = root.join("mem/syn");
    for name in ["features.npy", "opcodes.npy", "labels.npy"] {
        assert_eq!(
            std::fs::read(mem.join(name)).unwrap(),
            std::fs::read(out.join(name)).unwrap(),
            "{name}: streamed output differs from the in-memory path"
        );
    }
}

/// Multi-shard: the manifest must describe shards that reassemble —
/// lazily, shard by shard — into exactly the aligned in-memory dataset.
#[test]
fn streaming_datagen_multi_shard_manifest_reassembles() {
    let n = 50_000;
    let (functional, adjusted) = synthetic_pair(n, 0xCAFE);
    let cfg = FeatureConfig {
        nb: 64,
        nq: 8,
        nm: 16,
    };
    let root = std::env::temp_dir().join(format!("tao-int-dgN-{}", std::process::id()));

    let aligned = dataset::align(&functional, adjusted.clone()).unwrap();
    let ds = datagen::featurize(&aligned, cfg);
    datagen::write_dataset(&root, "mem", "syn", &ds).unwrap();

    let out = root.join("stream");
    let (manifest, _) = datagen::stream_dataset(
        &out,
        &functional.records[..],
        &adjusted.samples,
        adjusted.total_cycles,
        cfg,
        StreamOptions {
            chunk_size: 1_000,
            shards: 5,
            keep_shards: true,
        },
    )
    .unwrap();

    // The manifest tiles [0, n) with 5 contiguous shards.
    assert_eq!(manifest.rows, n);
    assert_eq!(manifest.shards.len(), 5);
    let mut next = 0usize;
    for e in &manifest.shards {
        assert_eq!(e.start, next);
        next += e.rows;
    }
    assert_eq!(next, n);
    // It round-trips through its JSON form (the lazy-consumer surface).
    assert_eq!(datagen::Manifest::load(&out).unwrap(), manifest);

    // Reassembly is byte-identical to the in-memory dataset files.
    datagen::merge_shards(&out, &manifest, true).unwrap();
    let mem = root.join("mem/syn");
    for name in ["features.npy", "opcodes.npy", "labels.npy"] {
        assert_eq!(
            std::fs::read(mem.join(name)).unwrap(),
            std::fs::read(out.join(name)).unwrap(),
            "{name}: multi-shard reassembly differs from the in-memory path"
        );
    }
    // merge_shards(remove) cleaned the shard files + manifest up.
    assert!(!out.join(datagen::shard_file("features", 0)).exists());
    assert!(!out.join("manifest.json").exists());
}

/// Chunk-source oracle gate, engine side: at 100k instructions the
/// in-memory columns, the chunk-streamed file reader and the live
/// functional generator must all drive the engine to identical
/// `Metrics`, batch for batch — the trace layout/transport must be
/// unobservable to the model.
#[test]
fn chunk_sources_identical_engine_metrics_at_100k() {
    use tao_sim::coordinator::engine::{self, ParallelOptions};
    use tao_sim::trace::{FileChunkSource, SliceChunkSource};

    let n: u64 = 100_000;
    let dir = std::env::temp_dir().join(format!("tao-int-csrc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = tao_sim::runtime::write_surrogate_artifact(&dir, "csrc", 64, 4).unwrap();
    let program = workloads::by_name("mcf").unwrap().build(17);
    let trace = FunctionalSim::new(&program).run(n);
    let cols = trace.to_columns();

    // In-memory reference.
    let mut s1 = tao_sim::runtime::Session::load(&artifact).unwrap();
    let r_mem = engine::simulate_columns(&mut s1, &cols, None, None).unwrap();
    assert_eq!(r_mem.metrics.instructions, n);

    // File-backed, streamed in odd-sized chunks.
    let path = dir.join("csrc.trace");
    tao_sim::trace::write_functional_columns(&path, &trace.name, &cols).unwrap();
    let mut s2 = tao_sim::runtime::Session::load(&artifact).unwrap();
    let mut file_src = FileChunkSource::open(&path).unwrap();
    let r_file = engine::simulate_chunked(&mut s2, &mut file_src, 7_777, None).unwrap();

    // Generator-backed: records exist only inside the pulled chunk.
    let mut s3 = tao_sim::runtime::Session::load(&artifact).unwrap();
    let mut gen_src = FunctionalSim::new(&program).into_chunks(n);
    let r_gen = engine::simulate_chunked(&mut s3, &mut gen_src, 4_096, None).unwrap();

    for (tag, r) in [("file", &r_file), ("generator", &r_gen)] {
        assert_eq!(r.metrics.instructions, r_mem.metrics.instructions, "{tag}");
        assert_eq!(r.metrics.cycles, r_mem.metrics.cycles, "{tag}");
        assert_eq!(r.metrics.mispredicts, r_mem.metrics.mispredicts, "{tag}");
        assert_eq!(r.metrics.l1d_misses, r_mem.metrics.l1d_misses, "{tag}");
        assert_eq!(r.batches, r_mem.batches, "{tag}");
    }

    // Parallel pull (warm-up handoff chunks) matches parallel slices on
    // the same grid: identical absorbed windows, and the f32 outputs sum
    // exactly in f64 at this scale, so equality is exact.
    let opts = ParallelOptions {
        chunk: 8_192,
        warmup: 1_024,
        pipeline: true,
    };
    let by_slice = engine::simulate_parallel_opts(&artifact, &cols, 3, None, opts).unwrap();
    let mut slice_src = SliceChunkSource::new(&cols, None).unwrap();
    let by_pull = engine::simulate_parallel_chunked(&artifact, &mut slice_src, 3, opts).unwrap();
    assert_eq!(by_pull.metrics.instructions, by_slice.metrics.instructions);
    assert_eq!(by_pull.metrics.cycles, by_slice.metrics.cycles);
    assert_eq!(by_pull.metrics.mispredicts, by_slice.metrics.mispredicts);
    assert_eq!(by_pull.batches, by_slice.batches);
}

/// Chunk-source oracle gate, datagen side: at 100k instructions the
/// generator-backed pull pipeline, the paired in-memory adapter and the
/// parallel sharded writer must produce byte-identical shard files,
/// merged arrays and manifests — and the fully in-memory featurize path
/// must match them byte for byte.
#[test]
fn chunk_sources_identical_datagen_outputs_at_100k() {
    let n: u64 = 100_000;
    let w = workloads::by_name("dee").unwrap();
    let uarch = UarchConfig::uarch_a();
    let cfg = FeatureConfig {
        nb: 64,
        nq: 8,
        nm: 16,
    };
    let stream = tao_sim::datagen::StreamOptions {
        chunk_size: 4_096,
        shards: 4,
        keep_shards: true,
    };
    let root = std::env::temp_dir().join(format!("tao-int-dsrc-{}", std::process::id()));

    // Materialized traces (shared by the in-memory oracle and the
    // resident-source writers; the generator path re-simulates its own).
    let adjusted = datagen::adjusted_trace(&w, &uarch, n, 23).unwrap();
    let program = w.build(23);
    let functional = FunctionalSim::new(&program).run(n);

    // In-memory oracle: featurize the full (already aligned) matrices.
    let ds = datagen::featurize(&adjusted, cfg);
    datagen::write_dataset(&root, "mem", "syn", &ds).unwrap();
    let dir_par = root.join("par");
    let (m_par, _) = datagen::stream_dataset(
        &dir_par,
        &functional.records[..],
        &adjusted.samples,
        adjusted.total_cycles,
        cfg,
        stream,
    )
    .unwrap();

    // Sequential pull over the paired in-memory adapter.
    let dir_adapter = root.join("adapter");
    let mut paired = datagen::PairedSliceSource::new(
        &functional.records[..],
        &adjusted.samples,
        adjusted.total_cycles,
    );
    let (m_adapter, _) =
        datagen::stream_dataset_source(&dir_adapter, &mut paired, cfg, stream).unwrap();

    // Generator-backed end to end: both simulators pulled in lockstep,
    // nothing materialized.
    let dir_gen = root.join("gen");
    let mut gen_src = datagen::SimPairSource::new(&w, &uarch, n, 23);
    let (m_gen, stats) =
        datagen::stream_dataset_source(&dir_gen, &mut gen_src, cfg, stream).unwrap();
    assert!(stats.peak_chunk_rows <= 4_096, "buffering exceeded the chunk bound");

    // Manifests and every shard file agree across all three writers.
    assert_eq!(m_par, m_adapter);
    assert_eq!(m_par, m_gen);
    assert_eq!(m_par.rows as u64, n);
    assert_eq!(m_par.shards.len(), 4);
    for e in &m_par.shards {
        for stem in ["features", "opcodes", "labels"] {
            let name = datagen::shard_file(stem, e.index);
            let reference = std::fs::read(dir_par.join(&name)).unwrap();
            assert_eq!(
                reference,
                std::fs::read(dir_adapter.join(&name)).unwrap(),
                "{name}: adapter shard differs"
            );
            assert_eq!(
                reference,
                std::fs::read(dir_gen.join(&name)).unwrap(),
                "{name}: generator shard differs"
            );
        }
    }

    // Merged canonical arrays are byte-identical to the in-memory path.
    datagen::merge_shards(&dir_gen, &m_gen, true).unwrap();
    let mem = root.join("mem/syn");
    for name in ["features.npy", "opcodes.npy", "labels.npy"] {
        assert_eq!(
            std::fs::read(mem.join(name)).unwrap(),
            std::fs::read(dir_gen.join(name)).unwrap(),
            "{name}: generator-streamed output differs from the in-memory path"
        );
    }
    assert_eq!(m_gen.total_cycles, ds.total_cycles);
}

/// Offline-pipelining acceptance gate: at 100k instructions, the
/// double-buffered stage/execute workers (+ dispatch-thread chunk
/// prefetch) must produce **identical** `Metrics` and batch counts to
/// the serial single-threaded staging across 1/2/4 workers — worker 1
/// exercising the sequential pipelined pull (`ChunkPrefetcher` +
/// executor thread) against the session-driven `simulate_chunked`.
#[test]
fn pipelined_parallel_chunked_identical_to_serial_at_100k() {
    use tao_sim::coordinator::engine::{self, ParallelOptions};
    use tao_sim::trace::SliceChunkSource;

    let n: u64 = 100_000;
    let dir = std::env::temp_dir().join(format!("tao-int-pipe-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = tao_sim::runtime::write_surrogate_artifact(&dir, "pipe", 64, 4).unwrap();
    let program = workloads::by_name("mcf").unwrap().build(29);
    let cols = FunctionalSim::new(&program).run(n).to_columns();
    let serial_opts = ParallelOptions {
        chunk: 8_192,
        warmup: 1_024,
        pipeline: false,
    };
    let piped_opts = ParallelOptions { pipeline: true, ..serial_opts };
    for workers in [1usize, 2, 4] {
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let serial = engine::simulate_parallel_chunked(&artifact, &mut src, workers, serial_opts)
            .unwrap();
        let mut src = SliceChunkSource::new(&cols, None).unwrap();
        let piped = engine::simulate_parallel_chunked(&artifact, &mut src, workers, piped_opts)
            .unwrap();
        assert_eq!(piped.metrics.instructions, n, "workers={workers}");
        assert_eq!(piped.metrics.instructions, serial.metrics.instructions);
        assert_eq!(piped.metrics.cycles, serial.metrics.cycles, "workers={workers}");
        assert_eq!(piped.metrics.mispredicts, serial.metrics.mispredicts);
        assert_eq!(piped.metrics.l1d_misses, serial.metrics.l1d_misses);
        assert_eq!(piped.metrics.l1i_misses, serial.metrics.l1i_misses);
        assert_eq!(piped.metrics.tlb_misses, serial.metrics.tlb_misses);
        assert_eq!(piped.batches, serial.batches, "workers={workers}");
        assert!(serial.pipeline.is_none(), "serial path must not report occupancy");
        let stats = piped.pipeline.expect("pipelined run reports occupancy");
        assert_eq!(stats.batches, piped.batches, "every batch rode the pipeline");
    }
}

/// Robustness regression: a `TAOTFNC1` trace file truncated mid-stream
/// must surface from the parallel chunked engine as a prompt *typed*
/// error — dispatch thread, workers, and per-worker pipelines all
/// unwinding cleanly — never a hang, a panic, or a partial result.
#[test]
fn parallel_chunked_propagates_mid_stream_truncation() {
    use tao_sim::coordinator::engine::{self, ParallelOptions};
    use tao_sim::trace::FileChunkSource;

    let n: u64 = 40_000;
    let dir = std::env::temp_dir().join(format!("tao-int-trunc-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let artifact = tao_sim::runtime::write_surrogate_artifact(&dir, "trunc", 64, 4).unwrap();
    let program = workloads::by_name("mcf").unwrap().build(31);
    let cols = FunctionalSim::new(&program).run(n).to_columns();
    let path = dir.join("trunc.trace");
    tao_sim::trace::write_functional_columns(&path, "trunc", &cols).unwrap();
    // Cut the file at ~60%: the header still promises `n` records, so
    // the parallel grid spins up and the puller hits the cut only
    // after several chunks are already in flight.
    let bytes = std::fs::read(&path).unwrap();
    std::fs::write(&path, &bytes[..bytes.len() * 3 / 5]).unwrap();

    for (workers, pipeline) in [(2usize, false), (2, true), (4, true)] {
        let opts = ParallelOptions { chunk: 2_048, warmup: 256, pipeline };
        let mut src = FileChunkSource::open(&path).unwrap();
        let t0 = std::time::Instant::now();
        let err = engine::simulate_parallel_chunked(&artifact, &mut src, workers, opts)
            .expect_err("truncated stream must fail");
        let msg = format!("{err:#}");
        assert!(
            msg.contains("truncated") || msg.contains("corrupt"),
            "untyped error (workers={workers}, pipeline={pipeline}): {msg}"
        );
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(60),
            "error path stalled (workers={workers}, pipeline={pipeline})"
        );
    }
}

/// Bounded-memory acceptance gate at the paper's "millions of
/// instructions" scale. `#[ignore]`d in the default (debug) test run;
/// CI's bounded-memory job runs it in release under a peak-RSS budget
/// that the materializing paths could not meet.
#[test]
#[ignore = "heavy: CI runs it via `cargo test --release -- --ignored million`"]
fn million_instruction_streaming_smoke() {
    let insts: u64 = 1_000_000;
    let w = workloads::by_name("dee").unwrap();
    let uarch = UarchConfig::uarch_a();
    let opts = DatagenOptions {
        instructions: insts,
        // Paper-default feature config: at F = 154 the in-memory [M, F]
        // matrix alone would be ~616 MB here — above the CI job's RSS
        // budget, so the bound is discriminating.
        features: FeatureConfig::default(),
        seed: 42,
        stream: StreamOptions {
            chunk_size: 8_192,
            shards: 4,
            keep_shards: false,
        },
        from_generator: true,
        from_trace: None,
    };
    let dir = std::env::temp_dir().join(format!("tao-1m-{}", std::process::id()));
    let (manifest, stats) = datagen::generate_streamed_source(&dir, &w, &uarch, &opts).unwrap();
    assert_eq!(manifest.rows as u64, insts);
    assert!(
        stats.peak_chunk_rows <= 8_192,
        "datagen buffering exceeded the chunk bound"
    );
    std::fs::remove_dir_all(&dir).ok();

    // Simulate: generator → parallel chunked inference, trace never
    // resident (peak ≈ workers × (chunk + warmup) records).
    let adir = std::env::temp_dir().join(format!("tao-1m-art-{}", std::process::id()));
    let artifact = tao_sim::runtime::write_surrogate_artifact(&adir, "smoke", 64, 4).unwrap();
    let program = w.build(42);
    let mut source = FunctionalSim::new(&program).into_chunks(insts);
    let popts = tao_sim::coordinator::engine::ParallelOptions {
        chunk: 16_384,
        warmup: 2_048,
        pipeline: true,
    };
    let r = tao_sim::coordinator::engine::simulate_parallel_chunked(&artifact, &mut source, 4, popts)
        .unwrap();
    assert_eq!(r.metrics.instructions, insts);
    assert!(r.metrics.cpi().is_finite() && r.metrics.cpi() > 0.0);
}

/// Trace serialization round-trips through disk at integration scale.
#[test]
fn trace_files_round_trip() {
    let dir = std::env::temp_dir().join(format!("tao-int-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let program = workloads::by_name("nab").unwrap().build(5);
    let functional = FunctionalSim::new(&program).run(4_000);
    let (detailed, _) = DetailedSim::new(&program, &UarchConfig::uarch_b()).run(4_000);
    let fpath = dir.join("nab.func");
    let dpath = dir.join("nab.det");
    tao_sim::trace::write_functional(&fpath, &functional).unwrap();
    tao_sim::trace::write_detailed(&dpath, &detailed).unwrap();
    let f2 = tao_sim::trace::read_functional(&fpath).unwrap();
    let d2 = tao_sim::trace::read_detailed(&dpath).unwrap();
    assert_eq!(f2.records, functional.records);
    assert_eq!(d2.records.len(), detailed.records.len());
    assert_eq!(d2.total_cycles, detailed.total_cycles);
}

/// The two on-disk trace formats are interchangeable at 100k
/// instructions: both round-trip the exact columns, v1 → v2 → v1
/// reproduces the original file byte for byte, and the parallel engine
/// computes identical metrics over either — the format never leaks
/// into the numbers.
#[test]
fn trace_formats_identical_columns_and_metrics_at_100k() {
    use tao_sim::coordinator::engine::{self, ParallelOptions};
    use tao_sim::trace::{
        open_trace_source, ChunkBuf, ChunkSource, TraceFormat, TraceSource, TraceWriteOptions,
    };

    let n: u64 = 100_000;
    let dir = std::env::temp_dir().join(format!("tao-int-v2fmt-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let program = workloads::by_name("mcf").unwrap().build(17);
    let trace = FunctionalSim::new(&program).run(n);
    let cols = trace.to_columns();

    let p1 = dir.join("mcf.v1.trace");
    let p2 = dir.join("mcf.v2.trace");
    TraceWriteOptions::default().write(&p1, &trace.name, &cols).unwrap();
    TraceWriteOptions::new(TraceFormat::V2)
        .chunk_rows(9_001)
        .write(&p2, &trace.name, &cols)
        .unwrap();

    // Both formats stream back the exact columns through the sniffing
    // opener, pulled in chunk sizes that straddle disk-chunk bounds.
    for (path, want) in [(&p1, TraceFormat::V1), (&p2, TraceFormat::V2)] {
        let mut src = open_trace_source(path).unwrap();
        assert_eq!(src.format(), want);
        assert_eq!(src.name(), trace.name);
        assert_eq!(src.len_hint(), Some(n as usize));
        let mut got = tao_sim::trace::TraceColumns::default();
        let mut buf = ChunkBuf::new();
        loop {
            let pulled = src.next_chunk(&mut buf, 7_777).unwrap();
            if pulled == 0 {
                break;
            }
            got.extend_from(&buf.cols, 0, pulled);
        }
        assert_eq!(got, cols, "{want} columns");
    }

    // Byte-level round trip: v1 → v2 → v1 reproduces the source file.
    let p2b = dir.join("mcf.conv.v2.trace");
    let p1b = dir.join("mcf.conv.v1.trace");
    let opts_v2 = TraceWriteOptions::new(TraceFormat::V2).chunk_rows(9_001);
    assert_eq!(tao_sim::trace::convert_trace(&p1, &p2b, &opts_v2).unwrap(), n);
    assert_eq!(
        std::fs::read(&p2).unwrap(),
        std::fs::read(&p2b).unwrap(),
        "direct v2 write vs v1→v2 transcode"
    );
    let opts_v1 = TraceWriteOptions::default();
    assert_eq!(tao_sim::trace::convert_trace(&p2b, &p1b, &opts_v1).unwrap(), n);
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p1b).unwrap(),
        "v1 → v2 → v1 byte identity"
    );

    // The parallel engine sees the same numbers through either format.
    let artifact = tao_sim::runtime::write_surrogate_artifact(&dir, "v2fmt", 64, 4).unwrap();
    let opts = ParallelOptions {
        chunk: 8_192,
        warmup: 1_024,
        pipeline: true,
    };
    let mut s1 = open_trace_source(&p1).unwrap();
    let r1 = engine::simulate_parallel_chunked(&artifact, &mut *s1, 3, opts).unwrap();
    let mut s2 = open_trace_source(&p2).unwrap();
    let r2 = engine::simulate_parallel_chunked(&artifact, &mut *s2, 3, opts).unwrap();
    assert_eq!(r1.metrics.instructions, n);
    assert_eq!(r2.metrics.instructions, r1.metrics.instructions);
    assert_eq!(r2.metrics.cycles, r1.metrics.cycles);
    assert_eq!(r2.metrics.mispredicts, r1.metrics.mispredicts);
    assert_eq!(r2.metrics.l1d_misses, r1.metrics.l1d_misses);
    assert_eq!(r2.batches, r1.batches);
}

/// Compression gate over a mixed serving suite: across the scenario
/// benches the column-specialized v2 format must be at least 4x
/// smaller than the flat v1 records.
#[test]
fn trace_v2_compresses_mixed_suite_at_least_4x() {
    use tao_sim::trace::{TraceFormat, TraceWriteOptions};
    use tao_sim::workloads::scenarios::{mixed_scenarios, ScenarioArtifact};

    let dir = std::env::temp_dir().join(format!("tao-int-v2zip-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let arts = vec![ScenarioArtifact { name: "tao_a".into(), simnet: false }];
    let jobs = mixed_scenarios(&arts, 8, 20_000, 77);
    let (mut v1_bytes, mut v2_bytes) = (0u64, 0u64);
    for (i, job) in jobs.iter().enumerate() {
        let program = workloads::by_name(&job.bench).unwrap().build(job.seed);
        let trace = FunctionalSim::new(&program).run(job.insts);
        let cols = trace.to_columns();
        let p1 = dir.join(format!("{i}.v1.trace"));
        let p2 = dir.join(format!("{i}.v2.trace"));
        TraceWriteOptions::default().write(&p1, &trace.name, &cols).unwrap();
        TraceWriteOptions::new(TraceFormat::V2).write(&p2, &trace.name, &cols).unwrap();
        v1_bytes += std::fs::metadata(&p1).unwrap().len();
        v2_bytes += std::fs::metadata(&p2).unwrap().len();
    }
    let ratio = v1_bytes as f64 / v2_bytes as f64;
    assert!(
        ratio >= 4.0,
        "mixed-suite compression ratio {ratio:.2}x ({v1_bytes} -> {v2_bytes} bytes), want >= 4x"
    );
}

/// PJRT end-to-end (needs `make artifacts`; skips otherwise): the engine
/// must process every instruction exactly once and produce finite,
/// plausible metrics, identically across worker counts modulo sharding.
#[test]
fn engine_end_to_end_with_artifact() {
    let artifact = std::path::Path::new("artifacts/tao_uarch_a.hlo.txt");
    if !artifact.exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let program = workloads::by_name("dee").unwrap().build(42);
    let trace = FunctionalSim::new(&program).run(6_000);
    let r1 = tao_sim::coordinator::engine::simulate_parallel(artifact, &trace.records, 1, None)
        .expect("simulate x1");
    assert_eq!(r1.metrics.instructions, 6_000);
    assert!(r1.metrics.cpi().is_finite() && r1.metrics.cpi() > 0.1);
    assert!(r1.metrics.branch_mpki() >= 0.0);
    // Determinism for fixed sharding.
    let r1b = tao_sim::coordinator::engine::simulate_parallel(artifact, &trace.records, 1, None)
        .expect("simulate x1 again");
    assert_eq!(r1.metrics.cycles, r1b.metrics.cycles);
}

/// The report harness smoke: table1 + figure2 run end to end (they write
/// under reports/ in the workspace).
#[test]
fn reports_smoke() {
    use tao_sim::cli::args::Args;
    let args = |s: &str| Args::new(s.split_whitespace().map(String::from).collect());
    tao_sim::reports::sim_reports::table1(args("--insts 2000")).expect("table1");
    tao_sim::reports::sim_reports::figure2(args("")).expect("figure2");
}
