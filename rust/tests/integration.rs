//! Cross-module integration tests: the full trace → dataset → features
//! pipeline, simulator cross-validation, randomized program properties,
//! streaming sharded datagen vs the in-memory oracle, and (when
//! `make artifacts` has run) the PJRT end-to-end path.

use tao_sim::datagen::{self, DatagenOptions, StreamOptions};
use tao_sim::dataset::{self, AdjustedTrace, Labels, Sample};
use tao_sim::detailed::DetailedSim;
use tao_sim::features::{FeatureConfig, FeatureExtractor};
use tao_sim::functional::FunctionalSim;
use tao_sim::isa::{Condition, Instruction, Opcode, Program, Reg};
use tao_sim::trace::{AccessLevel, FuncRecord, FunctionalTrace};
use tao_sim::uarch::UarchConfig;
use tao_sim::util::Rng;
use tao_sim::workloads;

/// The §4.1 pipeline end to end, every benchmark, every preset µarch:
/// traces align, totals are preserved, features have the right shape.
#[test]
fn dataset_pipeline_all_benchmarks_all_uarchs() {
    let insts = 3_000;
    for uarch in [UarchConfig::uarch_a(), UarchConfig::uarch_c()] {
        for w in workloads::suite() {
            let program = w.build(11);
            let functional = FunctionalSim::new(&program).run(insts);
            let (detailed, stats) = DetailedSim::new(&program, &uarch).run(insts);
            assert_eq!(stats.instructions, insts);
            let adjusted = dataset::adjust(&detailed);
            let aligned = dataset::align(&functional, adjusted)
                .unwrap_or_else(|e| panic!("{}/{}: {e}", uarch.name, w.name));
            assert_eq!(aligned.samples.len(), insts as usize);
            assert_eq!(
                aligned.reconstructed_cycles(),
                detailed.total_cycles,
                "{}/{}: Figure 2 invariant",
                uarch.name,
                w.name
            );
        }
    }
}

/// Functional and detailed simulators must commit identical streams for
/// *randomly generated* programs, not just the curated suite.
#[test]
fn property_random_programs_commit_identical_streams() {
    let mut rng = Rng::new(0xF00D);
    for trial in 0..25 {
        let len = 40 + rng.index(60);
        let program = random_program(&mut rng, len);
        if program.validate().is_err() {
            continue;
        }
        let n = 1_500;
        let functional = FunctionalSim::new(&program).run(n);
        let (detailed, _) = DetailedSim::new(&program, &UarchConfig::uarch_b()).run(n);
        let committed: Vec<_> = detailed.retired().map(|r| r.func).collect();
        assert_eq!(
            committed.len(),
            functional.records.len(),
            "trial {trial}: lengths differ"
        );
        for (i, (a, b)) in committed.iter().zip(&functional.records).enumerate() {
            assert_eq!(a, b, "trial {trial}: record {i} differs");
        }
    }
}

/// Random straight-line-plus-loops program generator for property tests.
fn random_program(rng: &mut Rng, len: usize) -> Program {
    let mut insts = Vec::with_capacity(len + 8);
    // Prologue: seed a few registers.
    for r in 1..6u8 {
        insts.push(
            Instruction::new(Opcode::Movi)
                .dst(Reg::x(r))
                .imm(rng.gen_range(1_000) as i64 + 1),
        );
    }
    let body_start = insts.len();
    for _ in 0..len {
        let pick = rng.index(10);
        let inst = match pick {
            0..=3 => {
                let ops = [Opcode::Add, Opcode::Sub, Opcode::Eor, Opcode::Orr, Opcode::Mul];
                Instruction::new(ops[rng.index(ops.len())])
                    .dst(Reg::x(1 + rng.index(8) as u8))
                    .src1(Reg::x(1 + rng.index(8) as u8))
                    .imm(rng.gen_range(64) as i64)
            }
            4..=5 => Instruction::new(Opcode::Ldr)
                .dst(Reg::x(1 + rng.index(8) as u8))
                .src1(Reg::x(1 + rng.index(8) as u8))
                .imm(rng.gen_range(512) as i64),
            6 => Instruction::new(Opcode::Str)
                .src1(Reg::x(1 + rng.index(8) as u8))
                .imm(rng.gen_range(512) as i64)
                .src3(Reg::x(1 + rng.index(8) as u8)),
            7 => {
                // Forward conditional skip (target patched below).
                Instruction::new(Opcode::Bcond)
                    .src1(Reg::x(1 + rng.index(8) as u8))
                    .imm(rng.gen_range(500) as i64)
                    .cond(Condition::Gt)
                    .target(usize::MAX)
            }
            _ => Instruction::new(Opcode::Nop),
        };
        insts.push(inst);
    }
    // Patch forward branches to valid targets.
    let end = insts.len();
    for i in body_start..end {
        if insts[i].target == Some(usize::MAX) {
            insts[i].target = Some((i + 1 + rng.index(4)).min(end));
        }
    }
    // Outer loop: x9 counts down from large; repeat body.
    insts.push(
        Instruction::new(Opcode::Subs)
            .dst(Reg::x(9))
            .src1(Reg::x(9))
            .imm(-1), // increments forever; cbnz below keeps looping
    );
    insts.push(Instruction::new(Opcode::Cbnz).src1(Reg::x(9)).target(body_start));
    Program {
        name: "random".into(),
        insts,
        data_size: 4096,
        init_words: vec![(0, 7), (8, 99)],
        init_regs: vec![(Reg::x(9), 1)],
    }
}

/// Feature extraction over real traces: deterministic, right shape, no
/// NaNs, and identical between the datagen path and a fresh extractor.
#[test]
fn feature_extraction_consistent_with_datagen() {
    let w = workloads::by_name("xal").unwrap();
    let uarch = UarchConfig::uarch_a();
    let opts = DatagenOptions {
        instructions: 2_000,
        ..Default::default()
    };
    let ds = datagen::generate(&w, &uarch, &opts).unwrap();
    // Recompute manually.
    let program = w.build(opts.seed);
    let functional = FunctionalSim::new(&program).run(opts.instructions);
    let cfg = FeatureConfig::default();
    let mut fx = FeatureExtractor::new(cfg);
    let mut row = vec![0.0f32; cfg.feature_dim()];
    for (i, rec) in functional.records.iter().enumerate() {
        let id = fx.extract_into(rec, &mut row);
        assert_eq!(id, ds.opcodes[i], "opcode id at {i}");
        let stored = &ds.features[i * cfg.feature_dim()..(i + 1) * cfg.feature_dim()];
        assert_eq!(stored, &row[..], "feature row {i}");
        assert!(row.iter().all(|v| v.is_finite()), "non-finite feature at {i}");
    }
}

/// Labels across microarchitectures: inputs identical, labels reflect the
/// design (µArch C strictly outperforms µArch A overall).
#[test]
fn labels_reflect_microarchitecture() {
    let w = workloads::by_name("dee").unwrap();
    let opts = DatagenOptions {
        instructions: 5_000,
        ..Default::default()
    };
    let a = datagen::generate(&w, &UarchConfig::uarch_a(), &opts).unwrap();
    let c = datagen::generate(&w, &UarchConfig::uarch_c(), &opts).unwrap();
    assert_eq!(a.features, c.features);
    assert!(a.total_cycles > c.total_cycles, "A should be slower than C");
}

/// Acceptance gate for the overlap-aware batcher: on a ≥100k-instruction
/// synthetic trace, the rolling-buffer batcher must stage byte-identical
/// batches to the seed's per-window ring copy, flush for flush
/// (the shared driver also asserts flush counts and partial flushes).
#[test]
fn overlap_batcher_byte_identical_to_naive_at_100k() {
    tao_sim::coordinator::engine::check_batcher_equivalence(32, 16, 128, 100_000, 0x0B17);
}

/// The SoA pipeline end to end: functional trace -> columns -> columnar
/// file round trip -> feature extraction parity with the AoS path.
#[test]
fn columnar_trace_pipeline_matches_aos() {
    let dir = std::env::temp_dir().join(format!("tao-cols-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let program = workloads::by_name("mcf").unwrap().build(9);
    let trace = FunctionalSim::new(&program).run(5_000);
    let cols = trace.to_columns();

    // Columnar serialization round trip, interoperable with the AoS
    // reader/writer.
    let path = dir.join("mcf.cols.trace");
    tao_sim::trace::write_functional_columns(&path, &trace.name, &cols).unwrap();
    let (name, cols2) = tao_sim::trace::read_functional_columns(&path).unwrap();
    assert_eq!(name, trace.name);
    assert_eq!(cols2, cols);
    assert_eq!(tao_sim::trace::read_functional(&path).unwrap(), trace);

    // Feature extraction over assembled columnar records matches AoS.
    let cfg = FeatureConfig::default();
    let mut fx_aos = FeatureExtractor::new(cfg);
    let mut fx_soa = FeatureExtractor::new(cfg);
    let mut row_a = vec![0.0f32; cfg.feature_dim()];
    let mut row_s = vec![0.0f32; cfg.feature_dim()];
    for (i, rec) in trace.records.iter().enumerate() {
        let ida = fx_aos.extract_into(rec, &mut row_a);
        let ids = fx_soa.extract_into(&cols.record(i), &mut row_s);
        assert_eq!(ida, ids, "opcode id at {i}");
        assert_eq!(row_a, row_s, "feature row {i}");
    }
}

/// Synthetic functional trace + matching adjusted trace, no simulators:
/// random opcode mix (branches and memory ops exercise every extractor
/// history structure), random-but-consistent labels.
fn synthetic_pair(n: usize, seed: u64) -> (FunctionalTrace, AdjustedTrace) {
    let mut rng = Rng::new(seed);
    let mut records = Vec::with_capacity(n);
    let mut samples = Vec::with_capacity(n);
    let mut clock = 0u64;
    let mut last_retire = 0u64;
    for i in 0..n {
        let opcode = match rng.index(10) {
            0..=3 => Opcode::Add,
            4..=5 => Opcode::Ldr,
            6 => Opcode::Str,
            7..=8 => Opcode::Bcond,
            _ => Opcode::Mul,
        };
        let is_mem = matches!(opcode, Opcode::Ldr | Opcode::Str);
        let rec = FuncRecord {
            pc: 0x400000 + (i as u64 % 4096) * 4,
            opcode,
            reg_bitmap: 1 + rng.index(255) as u64,
            mem_addr: if is_mem { 0x10000 + rng.index(1 << 16) as u64 } else { 0 },
            mem_bytes: if is_mem { 8 } else { 0 },
            taken: rng.chance(0.5),
        };
        records.push(rec);
        let fetch = 1 + rng.gen_range(3) as u32;
        let exec = 1 + rng.gen_range(20) as u32;
        clock += fetch as u64;
        last_retire = clock + exec as u64;
        samples.push(Sample {
            func: rec,
            labels: Labels {
                fetch_latency: fetch,
                exec_latency: exec,
                branch_mispred: opcode == Opcode::Bcond && rng.chance(0.1),
                access_level: if is_mem { AccessLevel::L1 } else { AccessLevel::None },
                icache_miss: rng.chance(0.01),
                tlb_miss: rng.chance(0.005),
            },
        });
    }
    let functional = FunctionalTrace {
        name: "synthetic".into(),
        records,
    };
    let adjusted = AdjustedTrace {
        name: "synthetic".into(),
        uarch: "synthetic".into(),
        samples,
        total_cycles: last_retire,
    };
    (functional, adjusted)
}

/// Streaming acceptance gate, single shard: a ~50k-row synthetic trace
/// streamed in 4k-row chunks (the trace is >10x larger than any chunk
/// buffer) must produce byte-identical `.npy` files to the seed's
/// in-memory featurize-then-write path.
#[test]
fn streaming_datagen_single_shard_byte_identical_at_50k() {
    let n = 50_000;
    let (functional, adjusted) = synthetic_pair(n, 0x5EED_DA7A);
    let cfg = FeatureConfig {
        nb: 128,
        nq: 16,
        nm: 32,
    };
    let root = std::env::temp_dir().join(format!("tao-int-dg1-{}", std::process::id()));

    // In-memory oracle.
    let aligned = dataset::align(&functional, adjusted.clone()).unwrap();
    assert_eq!(aligned.samples.len(), n);
    let ds = datagen::featurize(&aligned, cfg);
    datagen::write_dataset(&root, "mem", "syn", &ds).unwrap();

    // Streamed, one shard, 4k chunks.
    let chunk = 4_096;
    let out = root.join("stream");
    let (manifest, stats) = datagen::stream_dataset(
        &out,
        &functional.records[..],
        &adjusted.samples,
        adjusted.total_cycles,
        cfg,
        StreamOptions {
            chunk_size: chunk,
            shards: 1,
            keep_shards: true,
        },
    )
    .unwrap();
    datagen::merge_shards(&out, &manifest, false).unwrap();

    // Peak buffering really was bounded by the chunk size.
    assert!(stats.peak_chunk_rows <= chunk);
    assert_eq!(stats.chunks, (n as u64).div_ceil(chunk as u64));
    assert!(n >= 10 * chunk, "trace must dwarf the chunk buffer");

    let mem = root.join("mem/syn");
    for name in ["features.npy", "opcodes.npy", "labels.npy"] {
        assert_eq!(
            std::fs::read(mem.join(name)).unwrap(),
            std::fs::read(out.join(name)).unwrap(),
            "{name}: streamed output differs from the in-memory path"
        );
    }
}

/// Multi-shard: the manifest must describe shards that reassemble —
/// lazily, shard by shard — into exactly the aligned in-memory dataset.
#[test]
fn streaming_datagen_multi_shard_manifest_reassembles() {
    let n = 50_000;
    let (functional, adjusted) = synthetic_pair(n, 0xCAFE);
    let cfg = FeatureConfig {
        nb: 64,
        nq: 8,
        nm: 16,
    };
    let root = std::env::temp_dir().join(format!("tao-int-dgN-{}", std::process::id()));

    let aligned = dataset::align(&functional, adjusted.clone()).unwrap();
    let ds = datagen::featurize(&aligned, cfg);
    datagen::write_dataset(&root, "mem", "syn", &ds).unwrap();

    let out = root.join("stream");
    let (manifest, _) = datagen::stream_dataset(
        &out,
        &functional.records[..],
        &adjusted.samples,
        adjusted.total_cycles,
        cfg,
        StreamOptions {
            chunk_size: 1_000,
            shards: 5,
            keep_shards: true,
        },
    )
    .unwrap();

    // The manifest tiles [0, n) with 5 contiguous shards.
    assert_eq!(manifest.rows, n);
    assert_eq!(manifest.shards.len(), 5);
    let mut next = 0usize;
    for e in &manifest.shards {
        assert_eq!(e.start, next);
        next += e.rows;
    }
    assert_eq!(next, n);
    // It round-trips through its JSON form (the lazy-consumer surface).
    assert_eq!(datagen::Manifest::load(&out).unwrap(), manifest);

    // Reassembly is byte-identical to the in-memory dataset files.
    datagen::merge_shards(&out, &manifest, true).unwrap();
    let mem = root.join("mem/syn");
    for name in ["features.npy", "opcodes.npy", "labels.npy"] {
        assert_eq!(
            std::fs::read(mem.join(name)).unwrap(),
            std::fs::read(out.join(name)).unwrap(),
            "{name}: multi-shard reassembly differs from the in-memory path"
        );
    }
    // merge_shards(remove) cleaned the shard files + manifest up.
    assert!(!out.join(datagen::shard_file("features", 0)).exists());
    assert!(!out.join("manifest.json").exists());
}

/// Trace serialization round-trips through disk at integration scale.
#[test]
fn trace_files_round_trip() {
    let dir = std::env::temp_dir().join(format!("tao-int-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let program = workloads::by_name("nab").unwrap().build(5);
    let functional = FunctionalSim::new(&program).run(4_000);
    let (detailed, _) = DetailedSim::new(&program, &UarchConfig::uarch_b()).run(4_000);
    let fpath = dir.join("nab.func");
    let dpath = dir.join("nab.det");
    tao_sim::trace::write_functional(&fpath, &functional).unwrap();
    tao_sim::trace::write_detailed(&dpath, &detailed).unwrap();
    let f2 = tao_sim::trace::read_functional(&fpath).unwrap();
    let d2 = tao_sim::trace::read_detailed(&dpath).unwrap();
    assert_eq!(f2.records, functional.records);
    assert_eq!(d2.records.len(), detailed.records.len());
    assert_eq!(d2.total_cycles, detailed.total_cycles);
}

/// PJRT end-to-end (needs `make artifacts`; skips otherwise): the engine
/// must process every instruction exactly once and produce finite,
/// plausible metrics, identically across worker counts modulo sharding.
#[test]
fn engine_end_to_end_with_artifact() {
    let artifact = std::path::Path::new("artifacts/tao_uarch_a.hlo.txt");
    if !artifact.exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let program = workloads::by_name("dee").unwrap().build(42);
    let trace = FunctionalSim::new(&program).run(6_000);
    let r1 = tao_sim::coordinator::engine::simulate_parallel(artifact, &trace.records, 1, None)
        .expect("simulate x1");
    assert_eq!(r1.metrics.instructions, 6_000);
    assert!(r1.metrics.cpi().is_finite() && r1.metrics.cpi() > 0.1);
    assert!(r1.metrics.branch_mpki() >= 0.0);
    // Determinism for fixed sharding.
    let r1b = tao_sim::coordinator::engine::simulate_parallel(artifact, &trace.records, 1, None)
        .expect("simulate x1 again");
    assert_eq!(r1.metrics.cycles, r1b.metrics.cycles);
}

/// The report harness smoke: table1 + figure2 run end to end (they write
/// under reports/ in the workspace).
#[test]
fn reports_smoke() {
    use tao_sim::cli::args::Args;
    let args = |s: &str| Args::new(s.split_whitespace().map(String::from).collect());
    tao_sim::reports::sim_reports::table1(args("--insts 2000")).expect("table1");
    tao_sim::reports::sim_reports::figure2(args("")).expect("figure2");
}
