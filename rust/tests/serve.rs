//! Serving-subsystem integration tests: a real `tao serve` daemon on a
//! loopback socket, concurrent mixed jobs (Tao + SimNet artifacts,
//! preset and Table-3 context designs), and the correctness contract —
//! served per-job `Metrics` *identical* to the offline
//! `simulate_chunked` engine, cold cache and warm cache alike — plus
//! admission backpressure and graceful drain.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use tao_sim::runtime::ArtifactPool;
use tao_sim::serve::cli::write_surrogate_set;
use tao_sim::serve::http::{http_get, http_post};
use tao_sim::serve::loadgen::{assert_identical, offline_reference};
use tao_sim::serve::protocol::{JobOutcome, JobSpec, StatsSnapshot};
use tao_sim::serve::{ServeConfig, Server};
use tao_sim::workloads::{mixed_scenarios, ScenarioArtifact};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tao-serve-test-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn get_stats(addr: &str) -> StatsSnapshot {
    let resp = http_get(addr, "/v1/stats").unwrap();
    assert_eq!(resp.status, 200);
    StatsSnapshot::from_json(&resp.body).unwrap()
}

fn post_job(addr: &str, spec: &JobSpec) -> JobOutcome {
    let resp = http_post(addr, "/v1/simulate", &spec.to_json()).unwrap();
    assert_eq!(resp.status, 200, "job {spec:?} failed: {}", resp.body);
    JobOutcome::from_json(&resp.body).unwrap()
}

/// The tentpole contract: concurrent mixed jobs through the daemon,
/// every served result byte-identical to the offline engine — then a
/// second pass where every chunk hits the prediction cache, with
/// identical results, zero extra batches, and higher packed occupancy
/// than per-request execution would reach.
#[test]
fn loopback_concurrent_jobs_match_offline_cold_and_cached() {
    let dir = temp_dir("equality");
    let models = write_surrogate_set(&dir).unwrap();
    let pool = ArtifactPool::load(&models).unwrap();
    let batch = pool.get("serve_tao_a").unwrap().meta.batch as u64;
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 32,
        max_active: 16,
        cache_entries: 512,
        max_insts: 1_000_000,
        pipeline: true,
        admission_wait_ms: 100,
        // Jobs prepare off the lane thread: the loopback equality
        // assertions below prove the shared ExecPipeline + prep stage
        // leave served results bit-identical to the offline engine.
        prep_depth: 2,
    };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let arts = vec![
        ScenarioArtifact { name: "serve_tao_a".into(), simnet: false },
        ScenarioArtifact { name: "serve_tao_b".into(), simnet: false },
        ScenarioArtifact { name: "serve_simnet_a".into(), simnet: true },
    ];
    let specs: Vec<JobSpec> = mixed_scenarios(&arts, 12, 150, 7)
        .iter()
        .map(|j| JobSpec {
            bench: j.bench.clone(),
            insts: j.insts,
            seed: j.seed,
            artifact: j.artifact.clone(),
            chunk: 48,
            ctx_uarch: j.ctx_uarch.clone(),
        })
        .collect();

    let submit_all = |tag: &str| -> Vec<JobOutcome> {
        let mut outs: Vec<Option<JobOutcome>> = vec![None; specs.len()];
        std::thread::scope(|scope| {
            let handles: Vec<_> = specs
                .iter()
                .map(|spec| {
                    let addr = addr.clone();
                    scope.spawn(move || post_job(&addr, spec))
                })
                .collect();
            for (slot, h) in outs.iter_mut().zip(handles) {
                *slot = Some(h.join().unwrap_or_else(|_| panic!("{tag}: client panicked")));
            }
        });
        outs.into_iter().map(Option::unwrap).collect()
    };

    // Pass 1: cold cache. Every chunk misses; every window executes.
    let cold = submit_all("cold");
    let after_cold = get_stats(&addr);
    for (spec, out) in specs.iter().zip(&cold) {
        let offline = offline_reference(spec, &dir).unwrap();
        assert_identical(&out.metrics, &offline, &format!("cold {spec:?}")).unwrap();
        assert_eq!(out.metrics.instructions, spec.insts);
        assert_eq!(out.cache_hits, 0, "cold pass must not hit");
        assert_eq!(out.windows, spec.insts);
    }

    // Cross-job packing beats per-request batches: measured occupancy
    // must exceed what the same jobs would reach executing solo (each
    // padding its own tail to the batch boundary).
    let solo_slots: u64 = specs.iter().map(|s| s.insts.div_ceil(batch) * batch).sum();
    let solo_windows: u64 = specs.iter().map(|s| s.insts).sum();
    let solo_occupancy = solo_windows as f64 / solo_slots as f64;
    assert!(
        after_cold.occupancy() > solo_occupancy,
        "packed occupancy {:.3} must exceed solo occupancy {:.3}",
        after_cold.occupancy(),
        solo_occupancy
    );
    assert_eq!(after_cold.packed_windows, solo_windows);

    // Pass 2: warm cache. Identical metrics, every chunk hits, zero
    // additional model batches.
    let warm = submit_all("warm");
    let after_warm = get_stats(&addr);
    for (spec, out) in specs.iter().zip(&warm) {
        let offline = offline_reference(spec, &dir).unwrap();
        assert_identical(&out.metrics, &offline, &format!("warm {spec:?}")).unwrap();
        assert_eq!(
            out.cache_hits,
            spec.insts.div_ceil(spec.chunk as u64),
            "warm pass must hit every chunk of {spec:?}"
        );
        assert_eq!(out.windows, 0, "warm pass must skip model execution");
    }
    assert_eq!(after_warm.batches, after_cold.batches, "warm pass executed batches");
    assert!(after_warm.cache_hits > after_cold.cache_hits);

    // Graceful drain: shutdown, then the daemon exits cleanly.
    let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    let final_stats = srv.join().unwrap().unwrap();
    assert_eq!(final_stats.jobs_done, 2 * specs.len() as u64);
    assert_eq!(final_stats.active_jobs, 0);
    assert_eq!(final_stats.queue_depth, 0);

    // The socket is gone (or refuses) after drain.
    assert!(http_get(&addr, "/healthz").is_err(), "daemon still accepting after drain");
}

/// Admission control: with a single-slot lane and a single-slot queue,
/// a third concurrent job gets a retryable 429; draining finishes both
/// accepted jobs.
#[test]
fn backpressure_rejects_and_drain_finishes_in_flight_jobs() {
    let dir = temp_dir("backpressure");
    // T = 1 keeps per-window surrogate hashing cheap while the jobs
    // are long enough to stay in flight during the assertions.
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "bp", 8, 1).unwrap();
    let pool = ArtifactPool::load(&[hlo]).unwrap();
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 1,
        max_active: 1,
        cache_entries: 0,
        max_insts: 1_000_000,
        pipeline: true,
        admission_wait_ms: 0,
        // max_active bounds (active + in-prep), so job 2 stays in the
        // queue and the single-slot backpressure stays deterministic.
        prep_depth: 2,
    };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let spec = |seed: u64| JobSpec {
        bench: "mcf".into(),
        insts: 120_000,
        seed,
        artifact: "bp".into(),
        chunk: 4_096,
        ctx_uarch: None,
    };
    let wait_until = |pred: &dyn Fn(&StatsSnapshot) -> bool, what: &str| {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let s = get_stats(&addr);
            if pred(&s) {
                return;
            }
            assert!(Instant::now() < deadline, "timed out waiting for {what}: {s:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
    };

    std::thread::scope(|scope| {
        // Job 1 occupies the lane.
        let a = {
            let (addr, s) = (addr.clone(), spec(1));
            scope.spawn(move || post_job(&addr, &s))
        };
        wait_until(&|s| s.active_jobs == 1, "job 1 active");
        // Job 2 fills the queue's single slot.
        let b = {
            let (addr, s) = (addr.clone(), spec(2));
            scope.spawn(move || post_job(&addr, &s))
        };
        wait_until(&|s| s.queue_depth == 1, "job 2 queued");
        // Job 3 must bounce with a retryable 429.
        let resp = http_post(&addr, "/v1/simulate", &spec(3).to_json()).unwrap();
        assert_eq!(resp.status, 429, "expected backpressure, got: {}", resp.body);
        assert!(tao_sim::serve::protocol::error_retryable(&resp.body));

        // Drain mid-flight: both accepted jobs must still complete.
        let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
        assert_eq!(resp.status, 200);
        let out_a = a.join().unwrap();
        let out_b = b.join().unwrap();
        assert_eq!(out_a.metrics.instructions, 120_000);
        assert_eq!(out_b.metrics.instructions, 120_000);
        assert!(out_a.metrics.cycles > 0.0 && out_b.metrics.cycles > 0.0);
    });

    let final_stats = srv.join().unwrap().unwrap();
    assert_eq!(final_stats.jobs_done, 2);
    assert_eq!(final_stats.jobs_rejected, 1);
}
