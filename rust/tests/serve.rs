//! Serving-subsystem integration tests: a real `tao serve` daemon on a
//! loopback socket, concurrent mixed jobs (Tao + SimNet artifacts,
//! preset and Table-3 context designs), and the correctness contract —
//! served per-job `Metrics` *identical* to the offline
//! `simulate_chunked` engine, cold cache and warm cache alike — plus
//! admission backpressure, graceful drain, and the failure contract:
//! slow/oversized clients get typed timeouts, an executor panic
//! respawns the lane without losing accepted work, and the prediction
//! cache survives a restart through its journal.
//!
//! Fault probes are process-global, so every test here holds
//! `fault::exclusive()` — the loopback daemons traverse probe check
//! sites and a concurrently armed probe would cross-fire.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use tao_sim::runtime::ArtifactPool;
use tao_sim::serve::cli::write_surrogate_set;
use tao_sim::serve::http::{http_get, http_post, http_post_stalled};
use tao_sim::serve::loadgen::{assert_identical, offline_reference};
use tao_sim::serve::protocol::{ErrorCode, JobOutcome, JobSpec, ServeError, StatsSnapshot};
use tao_sim::serve::{ServeConfig, Server};
use tao_sim::util::fault::{self, Probe};
use tao_sim::workloads::{mixed_scenarios, ScenarioArtifact};

fn temp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("tao-serve-test-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

/// Baseline daemon config for these tests; individual tests override
/// the knobs they exercise.
fn test_config() -> ServeConfig {
    ServeConfig {
        addr: "127.0.0.1:0".into(),
        queue_depth: 32,
        max_active: 16,
        cache_entries: 512,
        max_insts: 1_000_000,
        pipeline: true,
        admission_wait_ms: 100,
        prep_depth: 2,
        read_timeout_ms: 10_000,
        write_timeout_ms: 30_000,
        default_deadline_ms: 300_000,
        cache_journal: None,
        ..ServeConfig::default()
    }
}

fn get_stats(addr: &str) -> StatsSnapshot {
    let resp = http_get(addr, "/v1/stats").unwrap();
    assert_eq!(resp.status, 200);
    StatsSnapshot::from_json(&resp.body).unwrap()
}

fn post_job(addr: &str, spec: &JobSpec) -> JobOutcome {
    let resp = http_post(addr, "/v1/simulate", &spec.to_json()).unwrap();
    assert_eq!(resp.status, 200, "job {spec:?} failed: {}", resp.body);
    JobOutcome::from_json(&resp.body).unwrap()
}

/// The tentpole contract: concurrent mixed jobs through the daemon,
/// every served result byte-identical to the offline engine — then a
/// second pass where every chunk hits the prediction cache, with
/// identical results, zero extra batches, and higher packed occupancy
/// than per-request execution would reach.
#[test]
fn loopback_concurrent_jobs_match_offline_cold_and_cached() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("equality");
    let models = write_surrogate_set(&dir).unwrap();
    let pool = ArtifactPool::load(&models).unwrap();
    let batch = pool.get("serve_tao_a").unwrap().meta.batch as u64;
    // Jobs prepare off the lane thread (prep_depth 2): the loopback
    // equality assertions below prove the shared ExecPipeline + prep
    // stage leave served results bit-identical to the offline engine.
    let cfg = test_config();
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let arts = vec![
        ScenarioArtifact { name: "serve_tao_a".into(), simnet: false },
        ScenarioArtifact { name: "serve_tao_b".into(), simnet: false },
        ScenarioArtifact { name: "serve_simnet_a".into(), simnet: true },
    ];
    let specs: Vec<JobSpec> = mixed_scenarios(&arts, 12, 150, 7)
        .iter()
        .map(|j| JobSpec {
            bench: j.bench.clone(),
            insts: j.insts,
            seed: j.seed,
            artifact: j.artifact.clone(),
            chunk: 48,
            ctx_uarch: j.ctx_uarch.clone(),
            deadline_ms: None,
            trace: None,
            plan: None,
            trace_id: None,
        })
        .collect();

    let submit_all = |tag: &str| -> Vec<JobOutcome> {
        let mut outs: Vec<Option<JobOutcome>> = vec![None; specs.len()];
        std::thread::scope(|scope| {
            let handles: Vec<_> = specs
                .iter()
                .map(|spec| {
                    let addr = addr.clone();
                    scope.spawn(move || post_job(&addr, spec))
                })
                .collect();
            for (slot, h) in outs.iter_mut().zip(handles) {
                *slot = Some(h.join().unwrap_or_else(|_| panic!("{tag}: client panicked")));
            }
        });
        outs.into_iter().map(Option::unwrap).collect()
    };

    // Pass 1: cold cache. Every chunk misses; every window executes.
    let cold = submit_all("cold");
    let after_cold = get_stats(&addr);
    for (spec, out) in specs.iter().zip(&cold) {
        let offline = offline_reference(spec, &dir).unwrap();
        assert_identical(&out.metrics, &offline, &format!("cold {spec:?}")).unwrap();
        assert_eq!(out.metrics.instructions, spec.insts);
        assert_eq!(out.cache_hits, 0, "cold pass must not hit");
        assert_eq!(out.windows, spec.insts);
    }

    // Cross-job packing beats per-request batches: measured occupancy
    // must exceed what the same jobs would reach executing solo (each
    // padding its own tail to the batch boundary).
    let solo_slots: u64 = specs.iter().map(|s| s.insts.div_ceil(batch) * batch).sum();
    let solo_windows: u64 = specs.iter().map(|s| s.insts).sum();
    let solo_occupancy = solo_windows as f64 / solo_slots as f64;
    assert!(
        after_cold.occupancy() > solo_occupancy,
        "packed occupancy {:.3} must exceed solo occupancy {:.3}",
        after_cold.occupancy(),
        solo_occupancy
    );
    assert_eq!(after_cold.packed_windows, solo_windows);

    // Pass 2: warm cache. Identical metrics, every chunk hits, zero
    // additional model batches.
    let warm = submit_all("warm");
    let after_warm = get_stats(&addr);
    for (spec, out) in specs.iter().zip(&warm) {
        let offline = offline_reference(spec, &dir).unwrap();
        assert_identical(&out.metrics, &offline, &format!("warm {spec:?}")).unwrap();
        assert_eq!(
            out.cache_hits,
            spec.insts.div_ceil(spec.chunk as u64),
            "warm pass must hit every chunk of {spec:?}"
        );
        assert_eq!(out.windows, 0, "warm pass must skip model execution");
    }
    assert_eq!(after_warm.batches, after_cold.batches, "warm pass executed batches");
    assert!(after_warm.cache_hits > after_cold.cache_hits);

    // Graceful drain: shutdown, then the daemon exits cleanly.
    let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    let final_stats = srv.join().unwrap().unwrap();
    assert_eq!(final_stats.jobs_done, 2 * specs.len() as u64);
    assert_eq!(final_stats.active_jobs, 0);
    assert_eq!(final_stats.queue_depth, 0);

    // The socket is gone (or refuses) after drain.
    assert!(http_get(&addr, "/healthz").is_err(), "daemon still accepting after drain");
}

/// Trace-replay jobs: a recorded trace posted as a `trace` job is read
/// transparently in either on-disk format and served bit-identically
/// to the equivalent generator-backed bench job; foreign files are
/// refused at admission with a non-retryable bad request.
#[test]
fn loopback_trace_jobs_match_bench_jobs_both_formats() {
    use tao_sim::trace::{TraceFormat, TraceWriteOptions};

    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("tracejobs");
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "tr", 8, 4).unwrap();
    let pool = ArtifactPool::load(&[hlo]).unwrap();
    // Cache off: the v1 and v2 jobs decode the same content, and a
    // warm hit would let the second skip its decode path entirely.
    let cfg = ServeConfig { cache_entries: 0, ..test_config() };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let insts: u64 = 30_000;
    let program = tao_sim::workloads::by_name("mcf").unwrap().build(9);
    let cols = tao_sim::functional::FunctionalSim::new(&program)
        .run(insts)
        .to_columns();
    let v1 = dir.join("mcf.v1.trace");
    let v2 = dir.join("mcf.v2.trace");
    TraceWriteOptions::default().write(&v1, "mcf", &cols).unwrap();
    TraceWriteOptions::new(TraceFormat::V2)
        .chunk_rows(4_096)
        .write(&v2, "mcf", &cols)
        .unwrap();

    let bench_spec = JobSpec {
        bench: "mcf".into(),
        insts,
        seed: 9,
        artifact: "tr".into(),
        chunk: 512,
        ctx_uarch: None,
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    };
    let bench_out = post_job(&addr, &bench_spec);
    assert_eq!(bench_out.metrics.instructions, insts);

    for (tag, path) in [("v1", &v1), ("v2", &v2)] {
        let tspec = JobSpec {
            bench: String::new(),
            insts: 0,
            seed: 9,
            artifact: "tr".into(),
            chunk: 512,
            ctx_uarch: None,
            deadline_ms: None,
            trace: Some(path.to_string_lossy().into_owned()),
            plan: None,
            trace_id: None,
        };
        let out = post_job(&addr, &tspec);
        assert_eq!(out.metrics.instructions, insts, "{tag} trace job length");
        assert_identical(&out.metrics, &bench_out.metrics, &format!("{tag} trace job"))
            .unwrap();
    }

    // Foreign/short files are refused at admission, not on a lane.
    let foreign = dir.join("foreign.trace");
    std::fs::write(&foreign, b"NOT A TRACE AT ALL").unwrap();
    let fspec = JobSpec {
        bench: String::new(),
        insts: 0,
        seed: 9,
        artifact: "tr".into(),
        chunk: 512,
        ctx_uarch: None,
        deadline_ms: None,
        trace: Some(foreign.to_string_lossy().into_owned()),
        plan: None,
        trace_id: None,
    };
    let resp = http_post(&addr, "/v1/simulate", &fspec.to_json()).unwrap();
    assert_eq!(resp.status, 400, "foreign trace must be a bad request: {}", resp.body);
    let err = ServeError::from_body(resp.status, &resp.body);
    assert!(!err.code.retryable(), "foreign trace refusal must not be retryable");

    let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    srv.join().unwrap().unwrap();
}

/// Admission control: with a single-slot lane and a single-slot queue,
/// a third concurrent job gets a retryable 429; draining finishes both
/// accepted jobs.
#[test]
fn backpressure_rejects_and_drain_finishes_in_flight_jobs() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("backpressure");
    // T = 1 keeps per-window surrogate hashing cheap while the jobs
    // are long enough to stay in flight during the assertions.
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "bp", 8, 1).unwrap();
    let pool = ArtifactPool::load(&[hlo]).unwrap();
    let cfg = ServeConfig {
        queue_depth: 1,
        max_active: 1,
        cache_entries: 0,
        admission_wait_ms: 0,
        // max_active bounds (active + in-prep), so job 2 stays in the
        // queue and the single-slot backpressure stays deterministic.
        ..test_config()
    };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let spec = |seed: u64| JobSpec {
        bench: "mcf".into(),
        insts: 120_000,
        seed,
        artifact: "bp".into(),
        chunk: 4_096,
        ctx_uarch: None,
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    };
    let wait_until = |pred: &dyn Fn(&StatsSnapshot) -> bool, what: &str| {
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let s = get_stats(&addr);
            if pred(&s) {
                return;
            }
            assert!(Instant::now() < deadline, "timed out waiting for {what}: {s:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
    };

    std::thread::scope(|scope| {
        // Job 1 occupies the lane.
        let a = {
            let (addr, s) = (addr.clone(), spec(1));
            scope.spawn(move || post_job(&addr, &s))
        };
        wait_until(&|s| s.active_jobs == 1, "job 1 active");
        // Job 2 fills the queue's single slot.
        let b = {
            let (addr, s) = (addr.clone(), spec(2));
            scope.spawn(move || post_job(&addr, &s))
        };
        wait_until(&|s| s.queue_depth == 1, "job 2 queued");
        // Job 3 must bounce with a retryable 429.
        let resp = http_post(&addr, "/v1/simulate", &spec(3).to_json()).unwrap();
        assert_eq!(resp.status, 429, "expected backpressure, got: {}", resp.body);
        assert!(tao_sim::serve::protocol::error_retryable(&resp.body));

        // Drain mid-flight: both accepted jobs must still complete.
        let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
        assert_eq!(resp.status, 200);
        let out_a = a.join().unwrap();
        let out_b = b.join().unwrap();
        assert_eq!(out_a.metrics.instructions, 120_000);
        assert_eq!(out_b.metrics.instructions, 120_000);
        assert!(out_a.metrics.cycles > 0.0 && out_b.metrics.cycles > 0.0);
    });

    let final_stats = srv.join().unwrap().unwrap();
    assert_eq!(final_stats.jobs_done, 2);
    assert_eq!(final_stats.jobs_rejected, 1);
}

/// Slow-client and oversized-request hardening: a client that stalls
/// mid-body past the read timeout gets a typed terminal 408 (not a
/// held connection), a request declaring a body over the 1 MiB cap
/// gets 413 at the header stage, and the daemon keeps serving real
/// traffic afterwards.
#[test]
fn stalled_reads_get_408_and_oversized_requests_get_413() {
    use std::io::{Read, Write};

    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("http-limits");
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "lim", 8, 1).unwrap();
    let pool = ArtifactPool::load(&[hlo]).unwrap();
    let cfg = ServeConfig { read_timeout_ms: 200, ..test_config() };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let spec = JobSpec {
        bench: "mcf".into(),
        insts: 2_000,
        seed: 9,
        artifact: "lim".into(),
        chunk: 512,
        ctx_uarch: None,
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    };

    // Stall mid-body for 5x the read timeout: the server must answer
    // a typed terminal 408 rather than hold the connection open.
    let resp =
        http_post_stalled(&addr, "/v1/simulate", &spec.to_json(), Duration::from_millis(1_000))
            .unwrap();
    assert_eq!(resp.status, 408, "stalled post got: {}", resp.body);
    let err = ServeError::from_body(resp.status, &resp.body);
    assert_eq!(err.code, ErrorCode::RequestTimeout);
    assert!(!err.code.retryable(), "client-pacing faults must not invite retries");

    // A declared body over MAX_BODY_BYTES is refused at the header
    // stage — before any body bytes are read — so send headers only.
    let mut stream = std::net::TcpStream::connect(&addr).unwrap();
    let req = format!(
        "POST /v1/simulate HTTP/1.1\r\nHost: {addr}\r\nContent-Length: {}\r\n\r\n",
        2 << 20
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut raw = String::new();
    stream.read_to_string(&mut raw).unwrap();
    assert!(raw.starts_with("HTTP/1.1 413"), "oversized post got: {raw}");
    assert!(raw.contains("too_large"), "413 body must carry the typed code: {raw}");
    drop(stream);

    // Abusive clients must not wedge the daemon for everyone else.
    let out = post_job(&addr, &spec);
    assert_eq!(out.metrics.instructions, spec.insts);

    let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    let final_stats = srv.join().unwrap().unwrap();
    assert_eq!(final_stats.jobs_done, 1);
}

/// Panic isolation: an injected executor panic kills the lane thread
/// mid-traffic; the supervisor must respawn it, in-flight jobs must
/// fail *retryably* (never hang, never exit the process), retries must
/// succeed with results still bit-identical to the offline engine, and
/// the drain must complete cleanly.
#[test]
fn executor_panic_respawns_lane_and_retried_jobs_match_offline() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("panic");
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "pn", 8, 1).unwrap();
    let pool = ArtifactPool::load(&[hlo]).unwrap();
    let cfg = ServeConfig { cache_entries: 0, ..test_config() };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let spec = |seed: u64| JobSpec {
        bench: "mcf".into(),
        insts: 20_000,
        seed,
        artifact: "pn".into(),
        chunk: 1_024,
        ctx_uarch: None,
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    };
    // One-shot: the second executor dispatch panics the lane thread
    // while several jobs are streaming through it.
    fault::arm_nth(Probe::ExecPanic, 2);

    let submit_retry = |seed: u64| -> JobOutcome {
        let body = spec(seed).to_json();
        let deadline = Instant::now() + Duration::from_secs(60);
        loop {
            let resp = http_post(&addr, "/v1/simulate", &body).unwrap();
            if resp.status == 200 {
                return JobOutcome::from_json(&resp.body).unwrap();
            }
            let err = ServeError::from_body(resp.status, &resp.body);
            assert!(err.code.retryable(), "terminal failure under panic fault: {err}");
            assert!(Instant::now() < deadline, "retries exhausted: {err}");
            std::thread::sleep(Duration::from_millis(20));
        }
    };
    let outs: Vec<JobOutcome> = std::thread::scope(|scope| {
        let sr = &submit_retry;
        let handles: Vec<_> = (0..4).map(|i| scope.spawn(move || sr(i))).collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    fault::disarm_all();

    for (i, out) in outs.iter().enumerate() {
        let offline = offline_reference(&spec(i as u64), &dir).unwrap();
        assert_identical(&out.metrics, &offline, &format!("post-panic job {i}")).unwrap();
    }
    let stats = get_stats(&addr);
    assert!(stats.lane_restarts >= 1, "lane never restarted: {stats:?}");

    let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    let final_stats = srv.join().unwrap().unwrap();
    assert_eq!(final_stats.active_jobs, 0);
    assert_eq!(final_stats.queue_depth, 0);
}

/// Drain under fault: an executor panic lands while the daemon is
/// draining with jobs still in flight. Every job must end typed —
/// completed or failed *retryably* — the drain must still exit
/// cleanly, and the cache journal must remain reloadable.
#[test]
fn drain_under_executor_panic_exits_clean_with_reloadable_journal() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("drain-fault");
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "df", 8, 1).unwrap();
    let pool = ArtifactPool::load(&[hlo]).unwrap();
    let journal = dir.join("drain.tjr");
    let _ = std::fs::remove_file(&journal);
    let cfg = ServeConfig { cache_journal: Some(journal.clone()), ..test_config() };
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let spec = |seed: u64| JobSpec {
        bench: "mcf".into(),
        insts: 120_000,
        seed,
        artifact: "df".into(),
        chunk: 4_096,
        ctx_uarch: None,
        deadline_ms: None,
        trace: None,
        plan: None,
        trace_id: None,
    };
    // One job to completion before the fault: its chunks are cached
    // and journaled, so the journal has content whatever happens to
    // the drain cohort below.
    let warm = post_job(&addr, &spec(100));
    assert_eq!(warm.metrics.instructions, 120_000);

    let results: Vec<Result<JobOutcome, ServeError>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4)
            .map(|i| {
                let (addr, s) = (addr.clone(), spec(i));
                scope.spawn(move || {
                    let resp = http_post(&addr, "/v1/simulate", &s.to_json()).unwrap();
                    if resp.status == 200 {
                        Ok(JobOutcome::from_json(&resp.body).unwrap())
                    } else {
                        Err(ServeError::from_body(resp.status, &resp.body))
                    }
                })
            })
            .collect();
        // Wait for traffic to be in flight, begin the drain, THEN arm
        // the panic so it fires on a dispatch during the drain (the
        // jobs above have thousands of batches left at this point).
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            let s = get_stats(&addr);
            if s.active_jobs >= 1 {
                break;
            }
            assert!(Instant::now() < deadline, "jobs never went active: {s:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
        assert_eq!(resp.status, 200);
        fault::arm_nth(Probe::ExecPanic, 1);
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    fault::disarm_all();

    // The drain-under-fault contract: every job ends *typed* — a 200
    // with full metrics or a retryable error — never a hang.
    for (i, r) in results.iter().enumerate() {
        match r {
            Ok(out) => assert_eq!(out.metrics.instructions, 120_000, "job {i}"),
            Err(se) => assert!(se.code.retryable(), "job {i} failed terminally: {se}"),
        }
    }
    // Clean exit ("process exits 0") even though a lane died mid-drain.
    let final_stats = srv.join().unwrap().unwrap();
    assert_eq!(final_stats.active_jobs, 0);
    assert_eq!(final_stats.queue_depth, 0);
    assert!(final_stats.lane_restarts >= 1, "panic never fired: {final_stats:?}");

    // The journal survived the faulted drain and is reloadable.
    let (_journal, recovered) = tao_sim::serve::CacheJournal::open(&journal).unwrap();
    assert!(!recovered.entries.is_empty(), "journal reloaded empty");
}

/// Crash-safe cache persistence: run jobs against a journaled daemon,
/// drain, then boot a *fresh* daemon on the same journal — the warm
/// pass must hit every chunk without executing a single model batch,
/// with metrics bit-identical to the first run.
#[test]
fn cache_journal_survives_daemon_restart() {
    let _gate = fault::exclusive();
    fault::disarm_all();
    let dir = temp_dir("journal");
    let hlo = tao_sim::runtime::write_surrogate_artifact(&dir, "jr", 8, 1).unwrap();
    let pool = ArtifactPool::load(std::slice::from_ref(&hlo)).unwrap();
    let journal = dir.join("cache.tjr");
    let cfg = ServeConfig { cache_journal: Some(journal.clone()), ..test_config() };

    let specs: Vec<JobSpec> = (0..3)
        .map(|seed| JobSpec {
            bench: "mcf".into(),
            insts: 10_000,
            seed,
            artifact: "jr".into(),
            chunk: 512,
            ctx_uarch: None,
            deadline_ms: None,
            trace: None,
            plan: None,
            trace_id: None,
        })
        .collect();

    // Run 1: journaled daemon, cold cache — every chunk executes and
    // is journaled as it is cached.
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());
    let first: Vec<JobOutcome> = specs.iter().map(|s| post_job(&addr, s)).collect();
    for out in &first {
        assert!(out.windows > 0, "cold run must execute");
    }
    let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    let stats1 = srv.join().unwrap().unwrap();
    assert_eq!(stats1.cache_recovered, 0);
    assert!(stats1.cache_entries > 0);
    assert!(journal.exists(), "journal file was never written");

    // Run 2: a fresh process-equivalent — new Server, same journal.
    let pool = ArtifactPool::load(&[hlo]).unwrap();
    let server = Server::bind(pool, &cfg).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());
    for (spec, cold) in specs.iter().zip(&first) {
        let warm = post_job(&addr, spec);
        assert_eq!(
            warm.cache_hits,
            spec.insts.div_ceil(spec.chunk as u64),
            "recovered cache must hit every chunk of {spec:?}"
        );
        assert_eq!(warm.windows, 0, "recovered cache must skip execution");
        assert_identical(&warm.metrics, &cold.metrics, &format!("journal {spec:?}")).unwrap();
    }
    let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    let stats2 = srv.join().unwrap().unwrap();
    assert_eq!(stats2.cache_recovered, stats1.cache_entries);
    assert_eq!(stats2.batches, 0, "warm daemon must not execute batches");
}

/// Telemetry reconciliation on a live daemon: the Prometheus `/metrics`
/// exposition parses, every family the CI `metrics-smoke` job greps for
/// is present, the structural identity `cache hits + misses == chunks`
/// holds exactly, the totals agree with both `/v1/stats` and the
/// client-side view of the same jobs, the per-lane `/v1/stats` detail
/// sums back to the daemon totals, and trace ids round-trip
/// (client-supplied echoed, server-minted otherwise).
#[test]
fn loopback_metrics_reconcile_with_stats_and_clients() {
    use tao_sim::telemetry::prometheus::{parse, sample_value};
    use tao_sim::util::json::Json;

    let _gate = fault::exclusive();
    fault::disarm_all();
    // The registry is process-global and `Server::bind` arms it; the
    // fault gate serializes every loopback test, so a reset here scopes
    // all counters to this daemon.
    tao_sim::telemetry::registry().reset();

    let dir = temp_dir("metrics");
    let models = write_surrogate_set(&dir).unwrap();
    let pool = ArtifactPool::load(&models).unwrap();
    let server = Server::bind(pool, &test_config()).unwrap();
    let addr = server.local_addr().unwrap().to_string();
    let srv = std::thread::spawn(move || server.run());

    let arts = vec![
        ScenarioArtifact { name: "serve_tao_a".into(), simnet: false },
        ScenarioArtifact { name: "serve_tao_b".into(), simnet: false },
    ];
    let mut specs: Vec<JobSpec> = mixed_scenarios(&arts, 8, 120, 11)
        .iter()
        .map(|j| JobSpec {
            bench: j.bench.clone(),
            insts: j.insts,
            seed: j.seed,
            artifact: j.artifact.clone(),
            chunk: 48,
            ctx_uarch: j.ctx_uarch.clone(),
            deadline_ms: None,
            trace: None,
            plan: None,
            trace_id: None,
        })
        .collect();
    specs[0].trace_id = Some("itest-trace_0".into());
    let outs: Vec<JobOutcome> = specs.iter().map(|s| post_job(&addr, s)).collect();

    // Trace ids: the client-supplied one echoes back verbatim; the rest
    // are server-minted and non-empty.
    assert_eq!(outs[0].trace_id, "itest-trace_0");
    for out in &outs[1..] {
        assert_eq!(out.trace_id.len(), 16, "minted trace id: {:?}", out.trace_id);
    }

    let resp = http_get(&addr, "/metrics").unwrap();
    assert_eq!(resp.status, 200);
    let samples = parse(&resp.body).expect("exposition must parse");
    for family in [
        "tao_jobs_submitted_total",
        "tao_jobs_done_total",
        "tao_jobs_active",
        "tao_jobs_chunks_total",
        "tao_queue_depth",
        "tao_cache_hits_total",
        "tao_cache_misses_total",
        "tao_cache_entries",
        "tao_lane_jobs_total",
        "tao_lane_batches_total",
        "tao_lanes_down",
        "tao_packed_windows_total",
        "tao_batch_slots_total",
        "tao_fault_checks_total",
        "tao_fault_fires_total",
        "tao_deadline_sweeps_total",
        "tao_errors_total",
        "tao_jobs_rejected_total",
    ] {
        assert!(
            sample_value(&samples, family, &[]).is_some(),
            "family {family} missing from /metrics"
        );
    }
    // Histogram families expose _count/_sum/_bucket series.
    for series in ["tao_request_seconds_count", "tao_queue_wait_seconds_count"] {
        assert!(
            sample_value(&samples, series, &[]).is_some(),
            "series {series} missing from /metrics"
        );
    }
    let v = |name: &str| sample_value(&samples, name, &[]).unwrap_or(0.0) as u64;

    // The structural identity the CI smoke job asserts: every chunk is
    // decided hit-or-miss at one site.
    assert_eq!(v("tao_cache_hits_total") + v("tao_cache_misses_total"), v("tao_jobs_chunks_total"));

    // Reconcile with the client-side view of the same jobs.
    let client_chunks: u64 = specs.iter().map(|s| s.insts.div_ceil(s.chunk as u64)).sum();
    let client_hits: u64 = outs.iter().map(|o| o.cache_hits).sum();
    assert_eq!(v("tao_jobs_chunks_total"), client_chunks);
    assert_eq!(v("tao_cache_hits_total"), client_hits);
    assert_eq!(v("tao_jobs_submitted_total"), specs.len() as u64);
    assert_eq!(v("tao_lane_jobs_total"), specs.len() as u64);

    // Reconcile with /v1/stats, including the per-lane detail object
    // (cells live in the registry, not the lane threads).
    let stats = get_stats(&addr);
    assert_eq!(v("tao_jobs_done_total"), stats.jobs_done);
    assert_eq!(v("tao_cache_hits_total"), stats.cache_hits);
    assert_eq!(v("tao_cache_misses_total"), stats.cache_misses);
    let raw = http_get(&addr, "/v1/stats").unwrap().body;
    let j = Json::parse(&raw).unwrap();
    let lanes = j.get("lanes").expect("/v1/stats lanes object");
    let mut lane_jobs_sum = 0u64;
    for name in ["serve_tao_a", "serve_tao_b", "serve_simnet_a"] {
        let lane = lanes.get(name).unwrap_or_else(|| panic!("lane {name} missing"));
        lane_jobs_sum += lane.req_u64("jobs_done").unwrap();
        assert_eq!(lane.req_u64("respawn_count").unwrap(), 0);
    }
    assert_eq!(lane_jobs_sum, stats.jobs_done);

    let resp = http_post(&addr, "/v1/shutdown", "").unwrap();
    assert_eq!(resp.status, 200);
    srv.join().unwrap().unwrap();
}
