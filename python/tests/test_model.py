"""Layer-2 model tests: shapes, loss behaviour, pallas/jnp parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(context=8, nq=8, nm=8, num_scalars=10, d_model=32, ff_dim=32, heads=2)


def batch(rng, b=4):
    ops = jnp.asarray(rng.integers(0, CFG.num_opcodes, size=(b, CFG.context)), jnp.int32)
    feats = jnp.asarray(rng.normal(size=(b, CFG.context, CFG.feature_dim)), jnp.float32)
    labels = jnp.asarray(
        np.stack(
            [
                rng.uniform(0, 10, b),
                rng.uniform(1, 100, b),
                rng.integers(0, 2, b).astype(float),
                rng.integers(0, 4, b).astype(float),
                rng.integers(0, 2, b).astype(float),
                rng.integers(0, 2, b).astype(float),
            ],
            axis=1,
        ),
        jnp.float32,
    )
    return ops, feats, labels


class TestForward:
    def test_output_shapes(self):
        rng = np.random.default_rng(0)
        params = M.init_params(jax.random.PRNGKey(0), CFG)
        ops, feats, _ = batch(rng, b=5)
        out = M.forward(params, ops, feats, CFG)
        assert out["fetch"].shape == (5,)
        assert out["exec"].shape == (5,)
        assert out["branch"].shape == (5,)
        assert out["access"].shape == (5, 4)
        assert out["icache"].shape == (5,)
        assert out["tlb"].shape == (5,)

    def test_pallas_and_jnp_paths_agree(self):
        rng = np.random.default_rng(1)
        params = M.init_params(jax.random.PRNGKey(1), CFG)
        ops, feats, _ = batch(rng)
        a = M.forward(params, ops, feats, CFG, use_pallas=False)
        b = M.forward(params, ops, feats, CFG, use_pallas=True)
        for k in a:
            np.testing.assert_allclose(
                np.asarray(a[k]), np.asarray(b[k]), rtol=1e-4, atol=1e-4,
                err_msg=f"output {k} diverges between kernel paths",
            )

    def test_prediction_depends_on_context(self):
        # Permuting the *context* instructions (not the last) must change
        # the prediction — self-attention sees the whole window.
        rng = np.random.default_rng(2)
        params = M.init_params(jax.random.PRNGKey(2), CFG)
        ops, feats, _ = batch(rng, b=1)
        out1 = M.forward(params, ops, feats, CFG)["fetch"]
        feats2 = jnp.asarray(feats).at[:, 0, :].set(feats[:, 1, :] * 2.0 + 1.0)
        out2 = M.forward(params, ops, feats2, CFG)["fetch"]
        assert abs(float(out1[0] - out2[0])) > 1e-7

    def test_deterministic(self):
        rng = np.random.default_rng(3)
        params = M.init_params(jax.random.PRNGKey(3), CFG)
        ops, feats, _ = batch(rng)
        a = M.forward(params, ops, feats, CFG)["exec"]
        b = M.forward(params, ops, feats, CFG)["exec"]
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestLoss:
    def test_loss_finite_and_decomposed(self):
        rng = np.random.default_rng(4)
        params = M.init_params(jax.random.PRNGKey(4), CFG)
        ops, feats, labels = batch(rng)
        total, parts = M.loss_fn(params, ops, feats, labels, CFG)
        assert np.isfinite(float(total))
        assert set(parts) == {"fetch", "exec", "branch", "access", "icache", "tlb"}
        recon = (
            CFG.w_fetch * parts["fetch"]
            + CFG.w_exec * parts["exec"]
            + CFG.w_branch * parts["branch"]
            + CFG.w_access * parts["access"]
            + CFG.w_icache * parts["icache"]
            + CFG.w_tlb * parts["tlb"]
        )
        np.testing.assert_allclose(float(total), float(recon), rtol=1e-6)

    def test_gradients_flow_to_all_parts(self):
        rng = np.random.default_rng(5)
        params = M.init_params(jax.random.PRNGKey(5), CFG)
        ops, feats, labels = batch(rng)
        grads = jax.grad(lambda p: M.loss_fn(p, ops, feats, labels, CFG)[0])(params)
        for section in ("embed", "adapt", "pred"):
            total = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads[section]))
            assert total > 0, f"no gradient reached {section}"

    def test_perfect_prediction_gives_small_latency_loss(self):
        # Construct labels equal to the model's own predictions: the
        # regression terms must then be ~0.
        rng = np.random.default_rng(6)
        params = M.init_params(jax.random.PRNGKey(6), CFG)
        ops, feats, labels = batch(rng)
        out = M.forward(params, ops, feats, CFG)
        labels = labels.at[:, M.LBL_FETCH].set(jnp.maximum(out["fetch"], 0.0))
        labels = labels.at[:, M.LBL_EXEC].set(jnp.maximum(out["exec"], 0.0))
        _, parts = M.loss_fn(params, ops, feats, labels, CFG)
        assert float(parts["fetch"]) < 1e-6 or float(parts["fetch"]) < float(parts["branch"])


class TestExportFn:
    def test_export_tuple_order(self):
        rng = np.random.default_rng(7)
        params = M.init_params(jax.random.PRNGKey(7), CFG)
        ops, feats, _ = batch(rng)
        fn = M.export_fn(params, CFG, use_pallas=False)
        out = fn(ops, feats)
        assert len(out) == 6
        named = M.forward(params, ops, feats, CFG)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(named["fetch"]))
        np.testing.assert_allclose(np.asarray(out[3]), np.asarray(named["access"]))
