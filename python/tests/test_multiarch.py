"""§4.3 transfer-learning machinery tests: gradient normalization, the
adaptation layer, scheme training loops, and fine-tuning with frozen
embeddings — on synthetic data (no datagen needed)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as data_mod
from compile import model as M
from compile import multiarch, optim

CFG = M.ModelConfig(context=4, nq=4, nm=4, num_scalars=10, d_model=16, ff_dim=16, heads=2)


def synth_bench(seed, n=600):
    rng = np.random.default_rng(seed)
    return data_mod.BenchData(
        name=f"synth{seed}",
        opcodes=rng.integers(0, CFG.num_opcodes, n).astype(np.int32),
        features=rng.normal(size=(n, CFG.feature_dim)).astype(np.float32),
        labels=np.stack(
            [
                rng.uniform(0, 5, n),
                rng.uniform(1, 20, n),
                rng.integers(0, 2, n).astype(float),
                rng.integers(0, 4, n).astype(float),
                rng.integers(0, 2, n).astype(float),
                rng.integers(0, 2, n).astype(float),
            ],
            axis=1,
        ).astype(np.float32),
        total_cycles=1000,
    )


def samplers():
    return {
        "arch_x": data_mod.WindowSampler([synth_bench(1)], CFG.context, 64, seed=0),
        "arch_y": data_mod.WindowSampler([synth_bench(2)], CFG.context, 64, seed=0),
    }


class TestNormalize:
    def test_normalize_centers_and_scales(self):
        g = {"w": jnp.asarray([[1.0, 2.0], [3.0, 5.0]])}
        n = multiarch._normalize(g)["w"]
        np.testing.assert_allclose(float(jnp.mean(n)), 0.0, atol=1e-6)
        rng = float(jnp.max(n) - jnp.min(n))
        np.testing.assert_allclose(rng, 1.0, atol=1e-5)

    def test_normalize_constant_gradient_is_safe(self):
        g = {"w": jnp.ones((3, 3))}
        n = multiarch._normalize(g)["w"]
        assert np.isfinite(np.asarray(n)).all()


class TestSchemes:
    def test_all_schemes_run_and_reduce_loss(self):
        for scheme in multiarch.SCHEMES:
            res = multiarch.train_shared(samplers(), CFG, scheme=scheme, epochs=3)
            first = np.mean(list(res.history[0]["loss"].values()))
            last = np.mean(list(res.history[-1]["loss"].values()))
            assert last < first, f"{scheme}: loss {first} -> {last}"

    def test_tao_scheme_trains_adaptation_layer(self):
        res = multiarch.train_shared(samplers(), CFG, scheme="tao", epochs=2)
        w = np.asarray(res.per_arch["arch_x"]["adapt"]["w_adapt"])
        assert not np.allclose(w, np.eye(CFG.d_model)), "adaptation layer never moved"

    def test_granite_keeps_adaptation_identity(self):
        res = multiarch.train_shared(samplers(), CFG, scheme="granite", epochs=2)
        w = np.asarray(res.per_arch["arch_x"]["adapt"]["w_adapt"])
        np.testing.assert_allclose(w, np.eye(CFG.d_model), atol=1e-6)

    def test_eval_fn_recorded_in_history(self):
        calls = []

        def eval_fn(embed, per_arch):
            calls.append(1)
            return 42.0

        res = multiarch.train_shared(samplers(), CFG, scheme="tao", epochs=2, eval_fn=eval_fn)
        assert len(calls) == 2
        assert res.history[0]["test_error"] == 42.0


class TestFinetune:
    def test_embeddings_frozen_during_finetune(self):
        shared = multiarch.train_shared(samplers(), CFG, scheme="tao", epochs=1)
        donor = shared.per_arch["arch_x"]["pred"]
        sampler = data_mod.WindowSampler([synth_bench(3)], CFG.context, 64, seed=0)
        before = jax.tree.map(np.copy, shared.embed)
        res = multiarch.finetune_unseen(shared.embed, donor, sampler, CFG, epochs=2)
        for k in before:
            np.testing.assert_array_equal(
                np.asarray(res.params["embed"][k]), before[k],
                err_msg=f"embedding {k} changed during fine-tune",
            )
        # Prediction layers must have moved.
        moved = any(
            not np.allclose(np.asarray(res.params["pred"][k]), np.asarray(donor[k]))
            for k in donor
        )
        assert moved
