"""Window batching / dataset plumbing tests (synthetic arrays)."""

import numpy as np
import pytest

from compile import data as data_mod


def bench(n=50, f=6):
    return data_mod.BenchData(
        name="t",
        opcodes=np.arange(n, dtype=np.int32),
        features=np.arange(n * f, dtype=np.float32).reshape(n, f),
        labels=np.tile(np.arange(n, dtype=np.float32)[:, None], (1, 6)),
        total_cycles=123,
    )


class TestWindowBatch:
    def test_window_contents(self):
        b = bench()
        ops, feats, labels = data_mod.window_batch(b, [4, 10], context=3)
        assert ops.shape == (2, 3)
        np.testing.assert_array_equal(ops[0], [2, 3, 4])
        np.testing.assert_array_equal(ops[1], [8, 9, 10])
        # Labels are those of the last (current) instruction.
        np.testing.assert_array_equal(labels[:, 0], [4, 10])
        # Features of the newest row.
        np.testing.assert_array_equal(feats[0, -1], b.features[4])

    def test_underrun_rejected(self):
        with pytest.raises(AssertionError):
            data_mod.window_batch(bench(), [1], context=3)


class TestWindowSampler:
    def test_epoch_covers_batches_without_duplicates_within_epoch(self):
        b = bench(n=100)
        s = data_mod.WindowSampler([b], context=4, batch=8, seed=0)
        seen = []
        for ops, feats, labels in s.epoch():
            assert ops.shape == (8, 4)
            seen.extend(labels[:, 0].tolist())
        assert len(seen) == len(s) * 8
        assert len(set(seen)) == len(seen)

    def test_max_windows_caps(self):
        b = bench(n=200)
        s = data_mod.WindowSampler([b], context=4, batch=8, seed=0, max_windows=16)
        assert len(s.index) == 16

    def test_multiple_benches_mixed(self):
        s = data_mod.WindowSampler([bench(60), bench(60)], context=4, batch=16, seed=1)
        batches = list(s.epoch())
        assert len(batches) == len(s)

    def test_short_bench_skipped(self):
        s = data_mod.WindowSampler([bench(2)], context=4, batch=2, seed=0)
        assert len(s.index) == 0


class TestSequentialWindows:
    def test_covers_every_instruction_once_in_order(self):
        b = bench(n=37)
        seen = []
        for idx, (ops, feats, labels) in data_mod.sequential_windows(b, context=4, batch=10):
            seen.extend(idx.tolist())
            # Labels must be the true rows even during warm-up.
            np.testing.assert_array_equal(labels[:, 0], idx.astype(np.float32))
        assert seen == list(range(37))
