"""Optimizer tests: Adam math, clipping, masking, LR schedule."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import optim


def tiny_params():
    return {"a": jnp.asarray([1.0, 2.0]), "b": {"c": jnp.asarray([[3.0]])}}


class TestAdam:
    def test_first_step_matches_hand_computation(self):
        cfg = optim.AdamConfig(lr=0.1, clip_norm=1e9)
        params = {"w": jnp.asarray([0.0])}
        grads = {"w": jnp.asarray([2.0])}
        state = optim.init_state(params)
        new, _ = optim.adam_step(params, grads, state, cfg)
        # First Adam step moves by ~lr regardless of gradient scale.
        np.testing.assert_allclose(float(new["w"][0]), -0.1, rtol=1e-5)

    def test_descends_quadratic(self):
        cfg = optim.AdamConfig(lr=0.05)
        params = {"w": jnp.asarray([5.0])}
        state = optim.init_state(params)
        for _ in range(200):
            g = {"w": 2 * params["w"]}
            params, state = optim.adam_step(params, g, state, cfg)
        assert abs(float(params["w"][0])) < 0.1

    def test_mask_freezes_parameters(self):
        cfg = optim.AdamConfig(lr=0.1)
        params = tiny_params()
        grads = jax.tree.map(jnp.ones_like, params)
        mask = optim.make_mask(params, lambda path: not path.startswith("a"))
        state = optim.init_state(params)
        new, _ = optim.adam_step(params, grads, state, cfg, mask=mask)
        np.testing.assert_array_equal(np.asarray(new["a"]), np.asarray(params["a"]))
        assert float(new["b"]["c"][0, 0]) != 3.0

    def test_clip_by_global_norm(self):
        grads = {"a": jnp.asarray([30.0, 40.0])}  # norm 50
        clipped = optim.clip_by_global_norm(grads, 5.0)
        np.testing.assert_allclose(float(optim.global_norm(clipped)), 5.0, rtol=1e-5)
        # Under the cap: unchanged.
        small = {"a": jnp.asarray([0.3, 0.4])}
        same = optim.clip_by_global_norm(small, 5.0)
        np.testing.assert_allclose(np.asarray(same["a"]), np.asarray(small["a"]), rtol=1e-6)

    def test_cosine_decay_reduces_lr(self):
        cfg = optim.AdamConfig(lr=0.1, decay_steps=10, min_lr_frac=0.1, clip_norm=1e9)
        params = {"w": jnp.asarray([0.0])}
        state = optim.init_state(params)
        # Run 10 steps with identical gradients; step sizes must shrink.
        deltas = []
        for _ in range(10):
            prev = float(params["w"][0])
            params, state = optim.adam_step(params, {"w": jnp.asarray([1.0])}, state, cfg)
            deltas.append(abs(float(params["w"][0]) - prev))
        assert deltas[-1] < deltas[0] * 0.5


class TestMask:
    def test_make_mask_paths(self):
        params = tiny_params()
        mask = optim.make_mask(params, lambda p: p == "b/c")
        assert float(mask["a"][0]) == 0.0
        assert float(mask["b"]["c"][0, 0]) == 1.0
