"""Layer-1 correctness: Pallas kernels vs the pure-jnp oracles.

This is THE core correctness signal for the kernel layer: hypothesis
sweeps shapes and value ranges; every case must match the oracle to
float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, embed, ref


def _rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


class TestMhaKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        b=st.integers(1, 4),
        h=st.integers(1, 4),
        t=st.sampled_from([4, 8, 16, 32]),
        dk=st.sampled_from([4, 8, 16]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_oracle_across_shapes(self, b, h, t, dk, seed):
        rng = np.random.default_rng(seed)
        q, k, v = (_rand(rng, (b, h, t, dk)) for _ in range(3))
        out = attention.mha(q, k, v)
        expect = ref.mha_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)

    def test_large_logits_numerically_stable(self):
        rng = np.random.default_rng(0)
        q = _rand(rng, (2, 2, 16, 8), scale=30.0)
        k = _rand(rng, (2, 2, 16, 8), scale=30.0)
        v = _rand(rng, (2, 2, 16, 8))
        out = np.asarray(attention.mha(q, k, v))
        assert np.isfinite(out).all()
        np.testing.assert_allclose(out, np.asarray(ref.mha_ref(q, k, v)), rtol=1e-4, atol=1e-4)

    def test_attention_rows_are_convex_combinations(self):
        # Output of softmax attention must lie within [min(v), max(v)]
        # per head/dim — a property check independent of the oracle.
        rng = np.random.default_rng(1)
        q, k = (_rand(rng, (1, 1, 8, 4)) for _ in range(2))
        v = _rand(rng, (1, 1, 8, 4))
        out = np.asarray(attention.mha(q, k, v))[0, 0]
        vmin = np.asarray(v)[0, 0].min(axis=0)
        vmax = np.asarray(v)[0, 0].max(axis=0)
        assert (out >= vmin - 1e-5).all() and (out <= vmax + 1e-5).all()

    def test_uniform_attention_when_q_is_zero(self):
        rng = np.random.default_rng(2)
        t = 8
        q = jnp.zeros((1, 1, t, 4))
        k = _rand(rng, (1, 1, t, 4))
        v = _rand(rng, (1, 1, t, 4))
        out = np.asarray(attention.mha(q, k, v))[0, 0]
        expect = np.asarray(v)[0, 0].mean(axis=0)
        np.testing.assert_allclose(out, np.tile(expect, (t, 1)), rtol=1e-5, atol=1e-5)

    def test_vmem_estimate_reasonable(self):
        # The §Perf harness sanity: block footprint fits well under a TPU
        # core's ~16 MiB VMEM at the exported shape.
        assert attention.vmem_bytes(32, 16) < 1 << 20
        assert attention.mxu_flops(256, 4, 32, 16) > 0


class TestLinearReluKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.sampled_from([128, 256, 512]),
        fin=st.integers(3, 160),
        fout=st.sampled_from([8, 64, 96]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_oracle(self, rows, fin, fout, seed):
        rng = np.random.default_rng(seed)
        x = _rand(rng, (rows, fin))
        w = _rand(rng, (fin, fout))
        bias = _rand(rng, (fout,))
        out = embed.linear_relu(x, w, bias)
        expect = ref.linear_relu_ref(x, w, bias)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)

    def test_output_nonnegative(self):
        rng = np.random.default_rng(3)
        x = _rand(rng, (128, 10), scale=5.0)
        w = _rand(rng, (10, 8))
        bias = _rand(rng, (8,))
        assert (np.asarray(embed.linear_relu(x, w, bias)) >= 0).all()

    def test_rejects_unaligned_rows(self):
        rng = np.random.default_rng(4)
        x = _rand(rng, (100, 10))  # not a multiple of ROW_BLOCK
        w = _rand(rng, (10, 8))
        bias = _rand(rng, (8,))
        with pytest.raises(AssertionError):
            embed.linear_relu(x, w, bias)


class TestKernelsInsideJit:
    def test_mha_composes_under_jit(self):
        # The kernel must lower inside an enclosing jit — that is exactly
        # what `aot.py` does when exporting the artifact. (Reverse-mode AD
        # through interpret-mode pallas is unsupported in this jax build;
        # training therefore differentiates the mathematically identical
        # jnp oracle, and inference parity is covered by
        # test_model.TestForward.test_pallas_and_jnp_paths_agree.)
        rng = np.random.default_rng(5)
        q, k, v = (_rand(rng, (1, 2, 8, 4)) for _ in range(3))

        @jax.jit
        def fn(q, k, v):
            return attention.mha(q, k, v) * 2.0

        out = np.asarray(fn(q, k, v))
        expect = 2.0 * np.asarray(ref.mha_ref(q, k, v))
        np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)
