"""AOT export tests: HLO text generation, metadata consistency, and the
large-constant regression (weights must survive into the text)."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile import model as M

CFG = M.ModelConfig(context=4, nq=4, nm=4, num_scalars=10, d_model=16, ff_dim=16, heads=2)

META = {
    "opcode_vocab": {f"op{i}": i for i in range(39)},
    "num_regs": 48,
    "feature_dim": CFG.feature_dim,
    "feature_config": {"nb": 16, "nq": 4, "nm": 4},
}


class TestHloExport:
    def test_to_hlo_text_keeps_large_constants(self):
        w = jnp.asarray(np.random.default_rng(0).normal(size=(64, 64)), jnp.float32)

        def fn(x):
            return (x @ w,)

        lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((8, 64), jnp.float32))
        text = aot.to_hlo_text(lowered)
        assert "{...}" not in text, "weights elided from HLO text"
        assert "f32[64,64]" in text

    def test_export_tao_writes_hlo_and_meta(self):
        params = M.init_params(jax.random.PRNGKey(0), CFG)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tao_test.hlo.txt")
            size = aot.export_tao(params, CFG, META, batch=2, path=path, use_pallas=False)
            assert size > 1000
            text = open(path).read()
            assert text.startswith("HloModule")
            meta = json.load(open(path.replace(".hlo.txt", ".meta.json")))
            assert meta["kind"] == "tao"
            assert meta["batch"] == 2
            assert meta["context"] == CFG.context
            assert meta["outputs"] == aot.OUTPUT_NAMES
            assert meta["kernel"] == "jnp"

    def test_export_pallas_variant(self):
        params = M.init_params(jax.random.PRNGKey(1), CFG)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tao_p.hlo.txt")
            aot.export_tao(params, CFG, META, batch=2, path=path, use_pallas=True)
            meta = json.load(open(path.replace(".hlo.txt", ".meta.json")))
            assert meta["kernel"] == "pallas"

    def test_vocab_hash_stable_and_sensitive(self):
        h1 = aot.vocab_hash(META)
        h2 = aot.vocab_hash(dict(META))
        assert h1 == h2
        changed = dict(META)
        changed["opcode_vocab"] = {**META["opcode_vocab"], "op0": 99}
        assert aot.vocab_hash(changed) != h1

    def test_model_config_from_meta(self):
        cfg = aot.model_config(META, context=4)
        assert cfg.feature_dim == META["feature_dim"]
        assert cfg.nq == 4 and cfg.nm == 4
        assert cfg.num_scalars == META["feature_dim"] - 48 - 4 - 4
