"""SimNet baseline tests."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import simnet

CFG = simnet.SimNetConfig(num_opcodes=39, feature_dim=20, context=6, channels=16)


def batch(rng, b=4):
    ops = jnp.asarray(rng.integers(0, CFG.num_opcodes, (b, CFG.context)), jnp.int32)
    feats = jnp.asarray(rng.normal(size=(b, CFG.context, CFG.feature_dim)), jnp.float32)
    ctx = jnp.asarray(rng.normal(size=(b, CFG.context, simnet.NUM_CTX_METRICS)), jnp.float32)
    return ops, feats, ctx


class TestSimNet:
    def test_forward_shapes(self):
        rng = np.random.default_rng(0)
        params = simnet.init_params(jax.random.PRNGKey(0), CFG)
        ops, feats, ctx = batch(rng, b=3)
        fetch, exe = simnet.forward(params, ops, feats, ctx, CFG)
        assert fetch.shape == (3,)
        assert exe.shape == (3,)

    def test_mask_current_zeroes_last_row_only(self):
        rng = np.random.default_rng(1)
        _, _, ctx = batch(rng)
        masked = simnet.mask_current(ctx)
        assert float(jnp.abs(masked[:, -1, :]).sum()) == 0.0
        np.testing.assert_array_equal(
            np.asarray(masked[:, :-1, :]), np.asarray(ctx[:, :-1, :])
        )

    def test_uses_context_metrics(self):
        # SimNet's defining property: µarch-specific context metrics move
        # the prediction (Tao's inputs are µarch-agnostic by contrast).
        rng = np.random.default_rng(2)
        params = simnet.init_params(jax.random.PRNGKey(2), CFG)
        ops, feats, ctx = batch(rng, b=1)
        f1, _ = simnet.forward(params, ops, feats, ctx, CFG)
        f2, _ = simnet.forward(params, ops, feats, ctx * 3.0, CFG)
        assert abs(float(f1[0] - f2[0])) > 1e-7

    def test_training_reduces_loss(self):
        rng = np.random.default_rng(3)

        def sampler():
            for _ in range(12):
                b = 32
                ops = rng.integers(0, CFG.num_opcodes, (b, CFG.context)).astype(np.int32)
                feats = rng.normal(size=(b, CFG.context, CFG.feature_dim)).astype(np.float32)
                lblw = rng.uniform(0, 4, size=(b, CFG.context, 6)).astype(np.float32)
                labels = lblw[:, -1, :]
                yield ops, feats, lblw, labels

        params, losses, secs = simnet.train(sampler, CFG, epochs=3, seed=0)
        assert losses[-1] < losses[0]
        assert secs > 0

    def test_export_fn_matches_forward(self):
        rng = np.random.default_rng(4)
        params = simnet.init_params(jax.random.PRNGKey(4), CFG)
        ops, feats, ctx = batch(rng)
        fn = simnet.export_fn(params, CFG)
        out = fn(ops, feats, ctx)
        direct = simnet.forward(params, ops, feats, ctx, CFG)
        np.testing.assert_allclose(np.asarray(out[0]), np.asarray(direct[0]))
        np.testing.assert_allclose(np.asarray(out[1]), np.asarray(direct[1]))
