"""AOT export: train the Tao + SimNet models and lower them to HLO text.

This is the single build-time entry point (`make artifacts`):

1. load the `.npy` datasets `tao datagen` wrote under ``data/``;
2. train microarchitecture-agnostic shared embeddings jointly on
   µArch A + µArch B with the Tao gradient scheme (§4.3);
3. per target architecture, fine-tune adaptation + prediction layers with
   frozen embeddings (µArch C demonstrates the unseen-arch path);
4. lower the inference functions — Pallas kernels included — to **HLO
   text** (`artifacts/tao_<arch>.hlo.txt`) plus a metadata JSON the Rust
   runtime validates at load time;
5. likewise train/export the SimNet baseline per architecture.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Python never runs at simulation time — the Rust coordinator loads these
artifacts through PJRT.
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import multiarch, optim, simnet
from . import train as train_mod

TRAIN_BENCHES = ["dee", "rom", "nab", "lee"]
OUTPUT_NAMES = ["fetch", "exec", "branch", "access", "icache", "tlb"]


def to_hlo_text(lowered):
    """Lower a jax-jitted computation to HLO text via stablehlo."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the baked weights ARE the model — the default
    # printer elides them as "{...}" which the text parser then silently
    # loads as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def vocab_hash(meta):
    """Stable hash of the opcode vocabulary (runtime load check)."""
    blob = json.dumps(meta["opcode_vocab"], sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]


def model_config(meta, context):
    fc = meta["feature_config"]
    num_scalars = meta["feature_dim"] - meta["num_regs"] - fc["nq"] - fc["nm"]
    return model_mod.ModelConfig(
        num_opcodes=len(meta["opcode_vocab"]),
        num_regs=meta["num_regs"],
        nq=fc["nq"],
        nm=fc["nm"],
        num_scalars=num_scalars,
        context=context,
    )


def export_tao(params, cfg, meta, batch, path, *, use_pallas=True):
    """Lower one trained Tao model and write artifact + metadata."""
    fn = model_mod.export_fn(params, cfg, use_pallas=use_pallas)
    ops_spec = jax.ShapeDtypeStruct((batch, cfg.context), jnp.int32)
    feat_spec = jax.ShapeDtypeStruct((batch, cfg.context, cfg.feature_dim), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(ops_spec, feat_spec))
    with open(path, "w") as f:
        f.write(text)
    side = {
        "kind": "tao",
        "batch": batch,
        "context": cfg.context,
        "feature_dim": cfg.feature_dim,
        "num_opcodes": cfg.num_opcodes,
        "latency_transform": "linear",
        "outputs": OUTPUT_NAMES,
        "feature_config": meta["feature_config"],
        "num_regs": meta["num_regs"],
        "vocab_hash": vocab_hash(meta),
        "kernel": "pallas" if use_pallas else "jnp",
    }
    with open(path.replace(".hlo.txt", ".meta.json"), "w") as f:
        json.dump(side, f, indent=2)
    return len(text)


def export_simnet(params, scfg, meta, batch, path):
    """Lower one trained SimNet model and write artifact + metadata."""
    fn = simnet.export_fn(params, scfg)
    ops_spec = jax.ShapeDtypeStruct((batch, scfg.context), jnp.int32)
    feat_spec = jax.ShapeDtypeStruct((batch, scfg.context, scfg.feature_dim), jnp.float32)
    ctx_spec = jax.ShapeDtypeStruct((batch, scfg.context, simnet.NUM_CTX_METRICS), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(ops_spec, feat_spec, ctx_spec))
    with open(path, "w") as f:
        f.write(text)
    side = {
        "kind": "simnet",
        "batch": batch,
        "context": scfg.context,
        "feature_dim": scfg.feature_dim,
        "num_opcodes": scfg.num_opcodes,
        "latency_transform": "linear",
        "outputs": ["fetch", "exec"],
        "feature_config": meta["feature_config"],
        "num_regs": meta["num_regs"],
        "vocab_hash": vocab_hash(meta),
        "kernel": "jnp",
    }
    with open(path.replace(".hlo.txt", ".meta.json"), "w") as f:
        json.dump(side, f, indent=2)
    return len(text)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--data", default="../data", help="datagen output dir")
    ap.add_argument("--out", default="../artifacts", help="artifact dir")
    ap.add_argument("--context", type=int, default=32, help="window length T")
    ap.add_argument("--batch", type=int, default=256, help="exported batch size B")
    ap.add_argument("--joint-epochs", type=int, default=3)
    ap.add_argument("--ft-epochs", type=int, default=3)
    ap.add_argument("--simnet-epochs", type=int, default=2)
    ap.add_argument("--train-batch", type=int, default=256)
    ap.add_argument("--max-windows", type=int, default=60_000,
                    help="cap on training windows per arch (build speed)")
    ap.add_argument("--uarchs", default="uarch_a,uarch_b,uarch_c")
    ap.add_argument("--shared", default="uarch_a,uarch_b",
                    help="archs used for shared-embedding training")
    ap.add_argument("--no-simnet", action="store_true")
    ap.add_argument("--kernel", choices=["pallas", "jnp", "both"], default="both",
                    help="kernel implementation lowered into the artifact; 'both' "
                         "writes tao_<arch>.hlo.txt (jnp — the CPU-PJRT hot path) "
                         "plus tao_<arch>.pallas.hlo.txt (the Layer-1 Pallas kernels; "
                         "interpret-mode lowering, slow on CPU but the faithful TPU "
                         "artifact — see DESIGN.md §7)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    t_start = time.perf_counter()
    os.makedirs(args.out, exist_ok=True)
    meta = data_mod.load_meta(args.data)
    cfg = model_config(meta, args.context)
    uarchs = args.uarchs.split(",")
    shared_archs = args.shared.split(",")
    # Shared-embedding training needs its archs' data even when they are
    # not export targets.
    load_archs = sorted(set(uarchs) | set(shared_archs))
    log = lambda msg: print(f"aot: {msg}", flush=True)

    # ---- load data ----
    benches = {
        u: data_mod.load_split(args.data, u, TRAIN_BENCHES) for u in load_archs
    }
    samplers = {
        u: data_mod.WindowSampler(
            benches[u], cfg.context, args.train_batch, seed=args.seed, max_windows=args.max_windows
        )
        for u in load_archs
    }

    # ---- stage 1: shared embeddings on the two selected archs (§4.3) ----
    log(f"stage 1: shared embeddings on {shared_archs} (scheme=tao)")
    shared = multiarch.train_shared(
        {u: samplers[u] for u in shared_archs},
        cfg,
        scheme="tao",
        epochs=args.joint_epochs,
        log=log,
        seed=args.seed,
    )
    log(f"stage 1 done in {shared.seconds:.1f}s")
    # Persist the shared embeddings + a donor prediction stack so the
    # build-time experiments (figure 14/15, table 5) can fine-tune new
    # designs without repeating stage 1.
    shared_state = {f"embed/{k}": np.asarray(v) for k, v in shared.embed.items()}
    donor = shared.per_arch[shared_archs[0]]["pred"]
    shared_state.update({f"pred/{k}": np.asarray(v) for k, v in donor.items()})
    np.savez(os.path.join(args.out, "shared_embeddings.npz"), **shared_state)

    # ---- stage 2: per-arch fine-tuning with frozen embeddings ----
    manifest = {"models": {}, "config": vars(args), "timings": {"shared_s": shared.seconds}}
    donor_arch = shared_archs[0]
    for u in uarchs:
        log(f"stage 2: fine-tune {u} (frozen embeddings)")
        if u in shared.per_arch:
            donor_pred = shared.per_arch[u]["pred"]
        else:
            donor_pred = shared.per_arch[donor_arch]["pred"]
        result = multiarch.finetune_unseen(
            shared.embed, donor_pred, samplers[u], cfg, epochs=args.ft_epochs, log=log
        )
        variants = {
            "both": [("", False), (".pallas", True)],
            "jnp": [("", False)],
            "pallas": [("", True)],
        }[args.kernel]
        for suffix, use_pallas in variants:
            path = os.path.join(args.out, f"tao_{u}{suffix}.hlo.txt")
            size = export_tao(result.params, cfg, meta, args.batch, path, use_pallas=use_pallas)
            log(f"exported {path} ({size / 1e6:.1f} MB hlo text)")
            manifest["models"][f"tao_{u}{suffix}"] = {
                "path": os.path.basename(path),
                "train_seconds": result.seconds,
                "final_loss": result.losses[-1] if result.losses else None,
            }

        if not args.no_simnet:
            scfg = simnet.SimNetConfig(
                num_opcodes=cfg.num_opcodes,
                feature_dim=cfg.feature_dim,
                context=cfg.context,
            )
            sampler_fn = simnet.ctx_sampler(samplers[u], benches[u])
            sparams, slosses, ssecs = simnet.train(
                sampler_fn, scfg, epochs=args.simnet_epochs, seed=args.seed, log=log
            )
            spath = os.path.join(args.out, f"simnet_{u}.hlo.txt")
            ssize = export_simnet(sparams, scfg, meta, args.batch, spath)
            log(f"exported {spath} ({ssize / 1e6:.1f} MB hlo text)")
            manifest["models"][f"simnet_{u}"] = {
                "path": os.path.basename(spath),
                "train_seconds": ssecs,
                "final_loss": slosses[-1] if slosses else None,
            }

    manifest["timings"]["total_s"] = time.perf_counter() - t_start
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    log(f"all artifacts written to {args.out} in {manifest['timings']['total_s']:.1f}s")


if __name__ == "__main__":
    main()
