"""Multi-architecture shared-embedding training — paper §4.3 / Figure 7.

Trains microarchitecture-*agnostic* embedding layers jointly over two
microarchitectures, comparing the three gradient-combination paradigms
from Figure 7:

* ``granite``   — average the raw shared-layer gradients (Figure 7a);
* ``gradnorm``  — learnable loss weights balancing gradient magnitudes
                  (Figure 7b, Chen et al. 2018);
* ``tao``       — per-architecture embedding **adaptation layer** (the
                  linear projection that rotates gradients and defeats
                  negative transfer) + per-architecture gradient
                  **normalization** ``(X − mean)/(max − min)`` before
                  averaging (Figure 7c / Algorithm 1);
* ``tao_noembed`` — ablation: gradient normalization without the
                  adaptation layer ("Tao w/o embed" in Figure 13).

The fine-tuning path for an unseen microarchitecture (Figure 6) freezes
the shared embeddings and trains only the adaptation + prediction layers.
"""

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as model_mod
from . import optim

SCHEMES = ("granite", "gradnorm", "tao", "tao_noembed")


@dataclasses.dataclass
class SharedTrainResult:
    """Outcome of shared-embedding training."""

    embed: dict
    per_arch: dict  # arch -> {"adapt", "pred"}
    history: list  # per-epoch dicts
    seconds: float


def init_shared_params(key, cfg, archs):
    """Shared embeddings + per-arch adaptation/prediction stacks."""
    k_embed, k_pred = jax.random.split(key)
    per_arch = {}
    for i, a in enumerate(archs):
        per_arch[a] = {
            "adapt": model_mod.init_adapt_params(cfg),
            "pred": model_mod.init_pred_params(jax.random.fold_in(k_pred, i), cfg),
        }
    return model_mod.init_embed_params(k_embed, cfg), per_arch


def _normalize(g):
    """Algorithm 1 line 5: (X − mean) / (max − min), per gradient matrix."""

    def norm_leaf(x):
        mean = jnp.mean(x)
        rng = jnp.max(x) - jnp.min(x)
        return (x - mean) / (rng + 1e-8)

    return jax.tree.map(norm_leaf, g)


def _arch_grads(cfg, use_adapt):
    """Jitted per-arch (loss, grads) over (embed, adapt, pred)."""

    def loss(embed, adapt, pred, opcodes, feats, labels):
        params = {"embed": embed, "adapt": adapt, "pred": pred}
        if not use_adapt:
            # Ablation: pin the adaptation layer to identity.
            params = {
                "embed": embed,
                "adapt": {"w_adapt": jnp.eye(cfg.d_model)},
                "pred": pred,
            }
        total, _ = model_mod.loss_fn(params, opcodes, feats, labels, cfg)
        return total

    @jax.jit
    def step(embed, adapt, pred, opcodes, feats, labels):
        (l, grads) = jax.value_and_grad(loss, argnums=(0, 1, 2))(
            embed, adapt, pred, opcodes, feats, labels
        )
        return l, grads

    return step


def train_shared(
    samplers,
    cfg,
    *,
    scheme="tao",
    epochs=2,
    adam_cfg=None,
    eval_fn=None,
    log=None,
    seed=0,
):
    """Joint training over `samplers` = {arch_name: WindowSampler}.

    `eval_fn(embed, per_arch) -> float` is called per epoch for the
    Figure 13 test-error history.
    """
    assert scheme in SCHEMES, scheme
    adam_cfg = adam_cfg or optim.AdamConfig()
    archs = list(samplers.keys())
    embed, per_arch = init_shared_params(jax.random.PRNGKey(seed), cfg, archs)
    # Only the full Tao scheme has the adaptation layer (Figure 7c);
    # granite/gradnorm/tao_noembed feed embeddings straight into the
    # prediction layers (Figure 7a/7b).
    use_adapt = scheme == "tao"
    step = _arch_grads(cfg, use_adapt)

    opt_embed = optim.init_state(embed)
    opt_arch = {a: optim.init_state(per_arch[a]) for a in archs}
    # GradNorm state.
    w = {a: 1.0 for a in archs}
    l0 = {a: None for a in archs}
    gn_alpha, gn_lr = 1.5, 0.025

    history = []
    t0 = time.perf_counter()
    for epoch in range(epochs):
        iters = [s.epoch() for s in samplers.values()]
        epoch_losses = {a: [] for a in archs}
        while True:
            batches = []
            try:
                for it in iters:
                    batches.append(next(it))
            except StopIteration:
                break
            g_embeds, g_archs, losses = {}, {}, {}
            for a, (opcodes, feats, labels) in zip(archs, batches):
                l, (ge, ga, gp) = step(
                    embed,
                    per_arch[a]["adapt"],
                    per_arch[a]["pred"],
                    jnp.asarray(opcodes),
                    jnp.asarray(feats),
                    jnp.asarray(labels),
                )
                losses[a] = float(l)
                epoch_losses[a].append(float(l))
                g_embeds[a] = ge
                g_archs[a] = {"adapt": ga, "pred": gp}
                if l0[a] is None:
                    l0[a] = max(float(l), 1e-6)

            # --- combine shared-layer gradients per scheme ---
            if scheme == "granite":
                combined = jax.tree.map(
                    lambda *gs: sum(gs) / len(gs), *[g_embeds[a] for a in archs]
                )
            elif scheme in ("tao", "tao_noembed"):
                normed = [_normalize(g_embeds[a]) for a in archs]
                combined = jax.tree.map(lambda *gs: sum(gs) / len(gs), *normed)
            elif scheme == "gradnorm":
                # Weighted gradients; weights updated toward balanced
                # per-task gradient norms scaled by inverse training rate.
                norms = {a: float(optim.global_norm(g_embeds[a])) * w[a] for a in archs}
                mean_norm = np.mean(list(norms.values()))
                rates = {a: losses[a] / l0[a] for a in archs}
                mean_rate = np.mean(list(rates.values()))
                for a in archs:
                    target = mean_norm * (rates[a] / mean_rate) ** gn_alpha
                    # dG_a/dw_a = G_a / w_a (norm is linear in the weight).
                    grad_w = np.sign(norms[a] - target) * norms[a] / max(w[a], 1e-6)
                    w[a] = max(w[a] - gn_lr * grad_w, 0.05)
                total_w = sum(w.values())
                for a in archs:
                    w[a] = w[a] * len(archs) / total_w
                combined = jax.tree.map(
                    lambda *gs: sum(gs) / len(gs),
                    *[jax.tree.map(lambda g: g * w[a], g_embeds[a]) for a in archs],
                )

            embed, opt_embed = optim.adam_step(embed, combined, opt_embed, adam_cfg)
            for a in archs:
                per_arch[a], opt_arch[a] = optim.adam_step(
                    per_arch[a], g_archs[a], opt_arch[a], adam_cfg
                )

        entry = {
            "epoch": epoch + 1,
            "loss": {a: float(np.mean(v)) if v else float("nan") for a, v in epoch_losses.items()},
        }
        if eval_fn is not None:
            entry["test_error"] = eval_fn(embed, per_arch)
        history.append(entry)
        if log:
            log(f"[{scheme}] epoch {epoch + 1}/{epochs}: {entry}")
    return SharedTrainResult(
        embed=embed, per_arch=per_arch, history=history, seconds=time.perf_counter() - t0
    )


def finetune_unseen(
    embed,
    donor_pred,
    sampler,
    cfg,
    *,
    epochs=2,
    adam_cfg=None,
    log=None,
):
    """Figure 6: adapt to an unseen µarch with frozen shared embeddings.

    The prediction layers are initialized from `donor_pred` (an earlier
    trained architecture) and fine-tuned together with a fresh adaptation
    layer; embedding parameters receive no updates.
    """
    from . import train as train_mod

    params = {
        "embed": embed,
        "adapt": model_mod.init_adapt_params(cfg),
        "pred": jax.tree.map(jnp.copy, donor_pred),
    }
    mask = optim.make_mask(params, lambda path: not path.startswith("embed"))
    result = train_mod.train(
        params, sampler, cfg, epochs=epochs, adam_cfg=adam_cfg, mask=mask, log=log
    )
    return result
