"""Single-architecture training and trace-level evaluation.

`train` fits a Tao model on one microarchitecture's windows; `evaluate`
replays a full benchmark through the model and reports the paper's
evaluation quantities: CPI (via the §4.2 retire-clock reconstruction),
branch/L1D/icache/TLB MPKI, and the §5 simulation-error percentages.
"""

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import optim


@dataclasses.dataclass
class TrainResult:
    """Outcome of a training run."""

    params: dict
    losses: list
    seconds: float
    epochs: int


def make_train_step(cfg, adam_cfg, mask=None):
    """Build a jitted Adam step over the combined multi-metric loss."""

    @jax.jit
    def step(params, opt_state, opcodes, feats, labels):
        (loss, parts), grads = jax.value_and_grad(model_mod.loss_fn, has_aux=True)(
            params, opcodes, feats, labels, cfg
        )
        params, opt_state = optim.adam_step(params, grads, opt_state, adam_cfg, mask=mask)
        return params, opt_state, loss, parts

    return step

def train(params, sampler, cfg, *, epochs=2, adam_cfg=None, mask=None, log=None):
    """Train `params` over `sampler` for `epochs`. Returns TrainResult."""
    adam_cfg = adam_cfg or optim.AdamConfig()
    step = make_train_step(cfg, adam_cfg, mask=mask)
    opt_state = optim.init_state(params)
    losses = []
    t0 = time.perf_counter()
    for epoch in range(epochs):
        epoch_losses = []
        for opcodes, feats, labels in sampler.epoch():
            params, opt_state, loss, _ = step(
                params, opt_state, jnp.asarray(opcodes), jnp.asarray(feats), jnp.asarray(labels)
            )
            epoch_losses.append(float(loss))
        avg = float(np.mean(epoch_losses)) if epoch_losses else float("nan")
        losses.append(avg)
        if log:
            log(f"epoch {epoch + 1}/{epochs}: loss {avg:.4f}")
    return TrainResult(params=params, losses=losses, seconds=time.perf_counter() - t0, epochs=epochs)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _predict_batch(params, opcodes, feats, cfg):
    out = model_mod.forward(params, opcodes, feats, cfg, use_pallas=False)
    return (
        jnp.maximum(out["fetch"], 0.0),
        jnp.maximum(out["exec"], 0.0),
        jax.nn.sigmoid(out["branch"]),
        jax.nn.softmax(out["access"], axis=-1),
        jax.nn.sigmoid(out["icache"]),
        jax.nn.sigmoid(out["tlb"]),
    )


def evaluate(params, bench, cfg, *, batch=512, max_insts=None):
    """Replay `bench` through the model; return predicted-vs-truth metrics.

    Mirrors what the Rust coordinator does on the request path, for use in
    the build-time experiments (Figures 12-14, Table 5).
    """
    n = len(bench) if max_insts is None else min(len(bench), max_insts)
    fetch = np.zeros(n)
    exe = np.zeros(n)
    mispred = np.zeros(n)
    access = np.zeros((n, model_mod.NUM_ACCESS_LEVELS))
    icache = np.zeros(n)
    tlb = np.zeros(n)
    for idx, (o, f, l) in data_mod.sequential_windows(bench, cfg.context, batch):
        idx = idx[idx < n]
        if len(idx) == 0:
            break
        o, f = o[: len(idx)], f[: len(idx)]
        pf, pe, pb, pa, pi, pt = _predict_batch(
            params, jnp.asarray(o), jnp.asarray(f), cfg
        )
        fetch[idx] = np.asarray(pf)
        exe[idx] = np.asarray(pe)
        mispred[idx] = np.asarray(pb)
        access[idx] = np.asarray(pa)
        icache[idx] = np.asarray(pi)
        tlb[idx] = np.asarray(pt)

    labels = bench.labels[:n]
    truth_cycles = _reconstruct(labels[:, 0], labels[:, 1])
    pred_cycles = _reconstruct(fetch, exe)
    # Aggregate MPKIs use *expected counts* (probability sums): the
    # sigmoid/softmax heads are probability-calibrated by their BCE/CE
    # losses, so the sum is an unbiased estimator of the miss count — far
    # better for MPKI than hard 0.5 thresholding on imbalanced classes.
    access_cls = np.argmax(access, axis=1)
    out = {
        "instructions": n,
        "cpi_truth": truth_cycles / n,
        "cpi_pred": pred_cycles / n,
        "branch_mpki_truth": labels[:, model_mod.LBL_MISPRED].sum() * 1000 / n,
        "branch_mpki_pred": mispred.sum() * 1000 / n,
        "l1d_mpki_truth": (labels[:, model_mod.LBL_ACCESS] >= 2).sum() * 1000 / n,
        "l1d_mpki_pred": access[:, 2:].sum() * 1000 / n,
        "icache_mpki_truth": labels[:, model_mod.LBL_ICACHE].sum() * 1000 / n,
        "icache_mpki_pred": icache.sum() * 1000 / n,
        "tlb_mpki_truth": labels[:, model_mod.LBL_TLB].sum() * 1000 / n,
        "tlb_mpki_pred": tlb.sum() * 1000 / n,
        "access_acc": float((access_cls == labels[:, model_mod.LBL_ACCESS]).mean()),
        "branch_auc_proxy": float(np.mean(mispred[labels[:, model_mod.LBL_MISPRED] > 0.5]) - np.mean(mispred[labels[:, model_mod.LBL_MISPRED] <= 0.5])) if (labels[:, model_mod.LBL_MISPRED] > 0.5).any() else 0.0,
    }
    out["cpi_error_pct"] = abs(out["cpi_pred"] - out["cpi_truth"]) / out["cpi_truth"] * 100
    return out


def _reconstruct(fetch_lat, exec_lat):
    """§4.2 retire-clock reconstruction: total cycles of a stream."""
    clock = np.cumsum(np.maximum(fetch_lat, 0.0))
    return float(clock[-1] + max(exec_lat[-1], 0.0)) if len(clock) else 0.0
