"""Layer-1 Pallas kernel: fused multi-head self-attention.

The compute hot-spot of Tao's prediction layers (§4.2). The paper ran
inference on A100s; per the hardware-adaptation note in DESIGN.md §7 the
kernel is re-thought for TPU rather than ported from CUDA:

* the grid is ``(B, H)`` — one (batch element, head) per program instance,
  so each instance's ``[T, Dk]`` Q/K/V blocks and the ``[T, T]`` score
  tile live entirely in VMEM (no HBM round-trip between QKᵀ, softmax and
  the V contraction — the fusion a CUDA version would do with shared
  memory and warp shuffles);
* both contractions (``q kᵀ`` and ``p v``) are expressed as
  ``jnp.dot(..., preferred_element_type=f32)`` so Mosaic maps them onto
  the MXU systolic array;
* the softmax row-reductions stay in registers/VMEM (VPU work), fused
  between the two MXU calls.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so interpret mode is both the correctness path (pytest vs
``ref.mha_ref``) and what `aot.py` lowers into the exported HLO. The VMEM
footprint / MXU utilization estimate for a real TPU lives in
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mha_kernel(q_ref, k_ref, v_ref, o_ref, *, scale):
    """One (batch, head) tile: q,k,v refs are ``[T, Dk]`` VMEM blocks."""
    q = q_ref[...]
    k = k_ref[...]
    v = v_ref[...]
    # MXU: [T, Dk] x [Dk, T] -> [T, T] scores.
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    # VPU: fused, numerically-stable softmax along keys.
    m = jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    # MXU: [T, T] x [T, Dk] -> [T, Dk].
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def mha(q, k, v, *, interpret=True):
    """Fused multi-head attention.

    Args:
      q, k, v: ``f32[B, H, T, Dk]``.
      interpret: run the Pallas kernel in interpret mode (required for CPU
        PJRT; real-TPU lowering would emit a Mosaic custom-call).

    Returns:
      ``f32[B, H, T, Dk]``.
    """
    b, h, t, dk = q.shape
    scale = 1.0 / (dk**0.5)
    # `None` squeezes the grid dims away: each instance sees [T, Dk] refs.
    spec = pl.BlockSpec((None, None, t, dk), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        functools.partial(_mha_kernel, scale=scale),
        grid=(b, h),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((b, h, t, dk), jnp.float32),
        interpret=interpret,
    )(q, k, v)


def vmem_bytes(t, dk):
    """Estimated VMEM footprint per program instance, in bytes.

    Q + K + V + O tiles (``4 · T·Dk``) plus the score/prob tile (``T²``)
    and softmax temporaries (``2·T``), all f32. Used by the §Perf harness
    to check the block fits comfortably under ~16 MiB/core VMEM.
    """
    return 4 * (4 * t * dk + t * t + 2 * t)


def mxu_flops(b, h, t, dk):
    """MXU FLOPs for one call (two matmuls per (batch, head) instance)."""
    per_instance = 2 * t * t * dk * 2  # two [T,T,Dk] contractions, 2 flops/MAC
    return b * h * per_instance
