"""Layer-1 Pallas kernel: fused linear + ReLU (embedding combine).

The §4.2 embedding stage concatenates per-category embeddings and pushes
them through a combining linear layer; at inference this is a single
``[B·T, Fin] × [Fin, Dout]`` GEMM executed every batch, second only to
attention in the profile. The kernel tiles rows into VMEM-sized blocks
(``ROW_BLOCK × Fin``), keeps the full weight resident (it is small:
Fin, Dout ≤ a few hundred), and fuses bias + ReLU after the MXU call so
the activation never round-trips to HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per program instance. 128 matches the MXU's systolic dimension.
ROW_BLOCK = 128


def _linear_relu_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    b = b_ref[...]
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    o_ref[...] = jnp.maximum(y, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def linear_relu(x, w, b, *, interpret=True):
    """Fused ``relu(x @ w + b)``.

    Args:
      x: ``f32[N, Fin]`` with ``N % ROW_BLOCK == 0`` (the model pads its
        flattened batch — see `model.embed_instructions`).
      w: ``f32[Fin, Fout]``.
      b: ``f32[Fout]``.

    Returns:
      ``f32[N, Fout]``.
    """
    n, fin = x.shape
    fout = w.shape[1]
    assert n % ROW_BLOCK == 0, f"row count {n} not a multiple of {ROW_BLOCK}"
    grid = (n // ROW_BLOCK,)
    return pl.pallas_call(
        _linear_relu_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, fin), lambda i: (i, 0)),
            pl.BlockSpec((fin, fout), lambda i: (0, 0)),
            pl.BlockSpec((fout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, fout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, fout), jnp.float32),
        interpret=interpret,
    )(x, w, b)
