"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: `pytest python/tests/test_kernels.py`
sweeps shapes/dtypes (hypothesis) and asserts the Pallas kernels match these
to tight tolerances. They are also used as the (mathematically identical)
fast path during build-time training, where XLA's native fusion beats
interpret-mode Pallas on CPU; the exported inference artifact uses the
Pallas kernels (see `aot.py --kernel`).
"""

import jax.numpy as jnp


def mha_ref(q, k, v):
    """Multi-head attention oracle.

    Args:
      q, k, v: ``f32[B, H, T, Dk]``.

    Returns:
      ``f32[B, H, T, Dk]`` — ``softmax(q kᵀ / sqrt(Dk)) v`` per (batch, head).
    """
    dk = q.shape[-1]
    scores = jnp.einsum("bhtd,bhsd->bhts", q, k) / jnp.sqrt(jnp.asarray(dk, q.dtype))
    # Numerically-stable softmax over the key axis.
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    weights = jnp.exp(scores)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return jnp.einsum("bhts,bhsd->bhtd", weights, v)


def linear_relu_ref(x, w, b):
    """Fused linear + ReLU oracle.

    Args:
      x: ``f32[N, Fin]``.
      w: ``f32[Fin, Fout]``.
      b: ``f32[Fout]``.

    Returns:
      ``f32[N, Fout]`` — ``relu(x @ w + b)``.
    """
    return jnp.maximum(x @ w + b, 0.0)
