"""Manual optimizers (the image has no optax): Adam + gradient clipping.

State and updates are plain pytrees, jit-friendly, with an optional
parameter *mask* so the §4.3 fine-tuning phase can freeze the shared
embedding layers ("the parameters of shared embedding layers are frozen,
i.e., we do not update the parameters during backpropagation").
"""

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    """Adam hyperparameters with optional cosine LR decay."""

    lr: float = 2e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    clip_norm: float = 5.0
    # Cosine decay to `lr * min_lr_frac` over `decay_steps` (0 = constant).
    decay_steps: int = 0
    min_lr_frac: float = 0.05


def init_state(params):
    """Zeroed first/second moments + step counter."""
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    """L2 norm across a whole pytree."""
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves))


def clip_by_global_norm(grads, max_norm):
    """Scale gradients so the global norm is at most `max_norm`."""
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale, grads)


def adam_step(params, grads, state, cfg: AdamConfig, *, mask=None):
    """One Adam update.

    Args:
      mask: optional pytree of 0/1 floats (same structure as params);
        masked-out (0) parameters receive no update — used to freeze the
        shared embedding layers during fine-tuning.

    Returns:
      (new_params, new_state).
    """
    grads = clip_by_global_norm(grads, cfg.clip_norm)
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: cfg.b1 * m_ + (1 - cfg.b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: cfg.b2 * v_ + (1 - cfg.b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1 - cfg.b1**tf
    bc2 = 1 - cfg.b2**tf
    if cfg.decay_steps > 0:
        frac = jnp.clip(tf / cfg.decay_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        lr = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos)
    else:
        lr = cfg.lr

    def upd(p, m_, v_):
        return p - lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + cfg.eps)

    new_params = jax.tree.map(upd, params, m, v)
    if mask is not None:
        new_params = jax.tree.map(
            lambda newp, oldp, mk: newp * mk + oldp * (1 - mk), new_params, params, mask
        )
    return new_params, {"m": m, "v": v, "t": t}


def make_mask(params, predicate):
    """Build a 0/1 mask pytree: `predicate(path_str)` decides per leaf.

    Paths are "/"-joined dict keys, e.g. ``"embed/w_comb"``.
    """

    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}" if path else k) for k, v in node.items()}
        return jnp.full_like(node, 1.0 if predicate(path) else 0.0)

    return walk(params, "")
