"""SimNet baseline — the state-of-the-art DL simulator Tao compares with.

Reproduces the relevant design points of SimNet's CNN ("C3 hybrid",
Li et al. 2022) for the paper's comparisons:

* **µarch-specific input**: alongside the static instruction features,
  SimNet consumes low-level performance metrics of the *context*
  instructions (branch misprediction, cache access levels, latencies) —
  which is exactly why it needs a fresh *detailed* trace per
  microarchitecture (Table 4's trace-generation column) while Tao reuses
  the functional trace.
* **CPI-only output**: fetch/execution latency of the current
  instruction; no branch/cache/TLB heads (Figure 9/11 comparisons).
* **Convolutional context aggregation**: three 1-D conv layers over the
  instruction window (the "C3" in C3 hybrid).

The current instruction's own metrics are masked from the input (they are
the prediction target).
"""

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import optim

NUM_CTX_METRICS = 6  # same label layout as datagen


@dataclasses.dataclass(frozen=True)
class SimNetConfig:
    """SimNet hyperparameters."""

    num_opcodes: int = 39
    feature_dim: int = 152
    context: int = 32
    op_embed: int = 24
    channels: int = 64
    kernel: int = 3


def init_params(key, cfg: SimNetConfig):
    """Initialize CNN parameters."""
    ks = jax.random.split(key, 8)

    def glorot(k, shape):
        fan_in = np.prod(shape[:-1])
        return jax.random.normal(k, shape, jnp.float32) * jnp.sqrt(2.0 / (fan_in + shape[-1]))

    in_dim = cfg.op_embed + cfg.feature_dim + NUM_CTX_METRICS
    c = cfg.channels
    return {
        "op_table": jax.random.normal(ks[0], (cfg.num_opcodes, cfg.op_embed)) * 0.1,
        "w_in": glorot(ks[1], (in_dim, c)),
        "b_in": jnp.zeros((c,)),
        # conv weights [K, Cin, Cout]
        "conv1": glorot(ks[2], (cfg.kernel, c, c)),
        "conv2": glorot(ks[3], (cfg.kernel, c, c)),
        "conv3": glorot(ks[4], (cfg.kernel, c, c)),
        "w_fetch": glorot(ks[5], (c, 1)),
        "b_fetch": jnp.zeros((1,)),
        "w_exec": glorot(ks[6], (c, 1)),
        "b_exec": jnp.zeros((1,)),
    }


def _conv1d(x, w):
    """Causal-ish same-padded conv over the window axis. x: [B,T,C]."""
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1,),
        padding="SAME",
        dimension_numbers=("NWC", "WIO", "NWC"),
    )


def forward(params, opcodes, feats, ctx_metrics, cfg: SimNetConfig):
    """Predict (fetch, exec) raw-cycle latencies of the last instruction.

    Args:
      opcodes: ``i32[B, T]``; feats: ``f32[B, T, F]``;
      ctx_metrics: ``f32[B, T, 6]`` — per-instruction metrics from the
        *detailed* trace, with the final (current) row masked by the
        caller.
    """
    x = jnp.concatenate([params["op_table"][opcodes], feats, ctx_metrics], axis=-1)
    x = jnp.maximum(x @ params["w_in"] + params["b_in"], 0.0)
    x = jnp.maximum(_conv1d(x, params["conv1"]), 0.0)
    x = jnp.maximum(_conv1d(x, params["conv2"]), 0.0)
    x = jnp.maximum(_conv1d(x, params["conv3"]), 0.0)
    h = x[:, -1, :]
    return (
        (h @ params["w_fetch"] + params["b_fetch"])[:, 0],
        (h @ params["w_exec"] + params["b_exec"])[:, 0],
    )


def mask_current(ctx_metrics):
    """Zero the final row (the current instruction's own metrics)."""
    return ctx_metrics.at[:, -1, :].set(0.0)


def loss_fn(params, opcodes, feats, ctx_metrics, labels, cfg: SimNetConfig):
    """MSE on raw-cycle latencies."""
    fetch, exe = forward(params, opcodes, feats, ctx_metrics, cfg)
    # Raw-space regression (see model.loss_fn for the rationale).
    l_f = jnp.mean((fetch - labels[:, 0]) ** 2)
    l_e = jnp.mean((exe - labels[:, 1]) ** 2)
    return 0.05 * (l_f + l_e)


def make_ctx_metrics(label_windows):
    """Build the context-metric tensor from label windows ``[B, T, 6]``
    (teacher forcing from the detailed trace), masking the current row."""
    return mask_current(jnp.asarray(label_windows))


def train(sampler_with_ctx, cfg: SimNetConfig, *, epochs=2, seed=0, adam_cfg=None, log=None):
    """Train SimNet. `sampler_with_ctx` yields (opcodes, feats, label_windows, labels)."""
    adam_cfg = adam_cfg or optim.AdamConfig()
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = optim.init_state(params)

    @jax.jit
    def step(params, opt_state, opcodes, feats, ctx, labels):
        loss, grads = jax.value_and_grad(loss_fn)(params, opcodes, feats, ctx, labels, cfg)
        params, opt_state = optim.adam_step(params, grads, opt_state, adam_cfg)
        return params, opt_state, loss

    losses = []
    t0 = time.perf_counter()
    for epoch in range(epochs):
        ep = []
        for opcodes, feats, label_windows, labels in sampler_with_ctx():
            ctx = make_ctx_metrics(label_windows)
            params, opt_state, loss = step(
                params, opt_state, jnp.asarray(opcodes), jnp.asarray(feats), ctx, jnp.asarray(labels)
            )
            ep.append(float(loss))
        losses.append(float(np.mean(ep)) if ep else float("nan"))
        if log:
            log(f"[simnet] epoch {epoch + 1}/{epochs}: loss {losses[-1]:.4f}")
    return params, losses, time.perf_counter() - t0


def export_fn(params, cfg: SimNetConfig):
    """Inference function for AOT lowering (weights baked)."""

    @functools.wraps(forward)
    def fn(opcodes, feats, ctx_metrics):
        fetch, exe = forward(params, opcodes, feats, ctx_metrics, cfg)
        return (fetch, exe)

    return fn


def ctx_sampler(sampler, benches):
    """Adapt a data.WindowSampler to also yield label windows.

    Reaches into the sampler's index to gather ``[B, T, 6]`` label
    windows alongside the standard batch.
    """

    def gen():
        order = sampler.rng.permutation(len(sampler.index))
        for start in range(0, len(order) - sampler.batch + 1, sampler.batch):
            chunk = sampler.index[order[start : start + sampler.batch]]
            ops, feats, lblw, labels = [], [], [], []
            offsets = np.arange(-(sampler.context - 1), 1)
            for bi in np.unique(chunk[:, 0]):
                rows = chunk[chunk[:, 0] == bi, 1]
                b = benches[bi]
                gather = rows[:, None] + offsets[None, :]
                ops.append(b.opcodes[gather])
                feats.append(b.features[gather])
                lblw.append(b.labels[gather])
                labels.append(b.labels[rows])
            yield (
                np.concatenate(ops),
                np.concatenate(feats),
                np.concatenate(lblw),
                np.concatenate(labels),
            )

    return gen
