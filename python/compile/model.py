"""Layer-2: Tao's multi-metric DL model (paper §4.2, Figure 5).

Architecture, exactly as the paper describes:

1. **Two-level embedding layers.** Per-category embeddings — a trainable
   lookup table for the opcode, separate linear embeddings for the
   register bitmap, branch history, access distances and scalar flags —
   concatenated and combined by a linear layer into the instruction
   embedding. (The embedding stack is the *shared, microarchitecture
   agnostic* part used for §4.3 transfer learning.)
2. **Per-architecture embedding adaptation layer** ``W_k`` — the linear
   projection Figure 7(c) inserts between shared embeddings and the
   prediction network (identity-initialized).
3. **Prediction layers.** Multi-head self-attention over the ``T = N+1``
   instruction window (the Pallas kernel of `kernels/attention.py`, or
   its jnp oracle during training) + a feed-forward trunk.
4. **Multi-metric heads** (§4.2): linear heads for fetch/execution
   latency (log1p space), a sigmoid head for branch misprediction, a
   softmax head over the four data-access levels, and sigmoid heads for
   icache and TLB misses.

Parameters are plain pytrees (dicts of jnp arrays) split into
``{"embed", "adapt", "pred"}`` so the §4.3 gradient schemes can address
the shared and per-architecture parts separately.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import attention as attn_kernel
from .kernels import embed as embed_kernel
from .kernels import ref as kref

# Label column indices (must match rust/src/datagen NUM_LABELS layout).
LBL_FETCH, LBL_EXEC, LBL_MISPRED, LBL_ACCESS, LBL_ICACHE, LBL_TLB = range(6)
NUM_ACCESS_LEVELS = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters. Feature layout mirrors data/meta.json."""

    num_opcodes: int = 39
    num_regs: int = 48
    nq: int = 32
    nm: int = 64
    num_scalars: int = 8
    context: int = 32  # T = N+1 window length
    op_embed: int = 24
    cat_embed: int = 16
    scalar_embed: int = 8
    d_model: int = 64
    heads: int = 4
    ff_dim: int = 64
    # Loss combination ratios (paper: "combined with a linear ratio").
    w_fetch: float = 0.05
    w_exec: float = 0.05
    w_branch: float = 0.5
    w_access: float = 0.5
    w_icache: float = 0.25
    w_tlb: float = 0.25

    @property
    def feature_dim(self):
        return self.num_regs + self.nq + self.nm + self.num_scalars

    @property
    def concat_dim(self):
        return self.op_embed + 3 * self.cat_embed + self.scalar_embed

    @property
    def dk(self):
        assert self.d_model % self.heads == 0
        return self.d_model // self.heads


def _layernorm(x, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps)


def _glorot(key, shape):
    fan_in, fan_out = shape[0], shape[-1]
    scale = jnp.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, shape, jnp.float32) * scale


def init_embed_params(key, cfg: ModelConfig):
    """Shared (microarchitecture-agnostic) embedding parameters."""
    ks = jax.random.split(key, 7)
    return {
        "op_table": jax.random.normal(ks[0], (cfg.num_opcodes, cfg.op_embed)) * 0.1,
        "w_reg": _glorot(ks[1], (cfg.num_regs, cfg.cat_embed)),
        "w_br": _glorot(ks[2], (cfg.nq, cfg.cat_embed)),
        "w_mem": _glorot(ks[3], (cfg.nm, cfg.cat_embed)),
        "w_sc": _glorot(ks[4], (cfg.num_scalars, cfg.scalar_embed)),
        "w_comb": _glorot(ks[5], (cfg.concat_dim, cfg.d_model)),
        "b_comb": jnp.zeros((cfg.d_model,)),
    }


def init_adapt_params(cfg: ModelConfig):
    """Per-architecture embedding adaptation layer (identity init)."""
    return {"w_adapt": jnp.eye(cfg.d_model, dtype=jnp.float32)}


def init_pred_params(key, cfg: ModelConfig):
    """Per-architecture prediction-layer parameters."""
    ks = jax.random.split(key, 10)
    d = cfg.d_model
    return {
        "wq": _glorot(ks[0], (d, d)),
        "wk": _glorot(ks[1], (d, d)),
        "wv": _glorot(ks[2], (d, d)),
        "wo": _glorot(ks[3], (d, d)),
        "w_ff": _glorot(ks[4], (d, cfg.ff_dim)),
        "b_ff": jnp.zeros((cfg.ff_dim,)),
        "w_fetch": _glorot(ks[5], (cfg.ff_dim, 1)),
        "b_fetch": jnp.zeros((1,)),
        "w_exec": _glorot(ks[6], (cfg.ff_dim, 1)),
        "b_exec": jnp.zeros((1,)),
        "w_branch": _glorot(ks[7], (cfg.ff_dim, 1)),
        "b_branch": jnp.zeros((1,)),
        "w_access": _glorot(ks[8], (cfg.ff_dim, NUM_ACCESS_LEVELS)),
        "b_access": jnp.zeros((NUM_ACCESS_LEVELS,)),
        "w_icache": _glorot(ks[9], (cfg.ff_dim, 1)),
        "b_icache": jnp.zeros((1,)),
        "w_tlb": _glorot(jax.random.fold_in(key, 99), (cfg.ff_dim, 1)),
        "b_tlb": jnp.zeros((1,)),
    }


def init_params(key, cfg: ModelConfig):
    """Full parameter set for a single-architecture model."""
    k1, k2 = jax.random.split(key)
    return {
        "embed": init_embed_params(k1, cfg),
        "adapt": init_adapt_params(cfg),
        "pred": init_pred_params(k2, cfg),
    }


def embed_instructions(embed, opcodes, feats, cfg: ModelConfig, *, use_pallas=False):
    """Two-level embedding: per-category embeddings → combine linear.

    Args:
      embed: embedding params.
      opcodes: ``i32[B, T]``.
      feats: ``f32[B, T, F]``.

    Returns:
      ``f32[B, T, d_model]`` instruction embeddings.
    """
    r, q, m = cfg.num_regs, cfg.nq, cfg.nm
    regs = feats[..., :r]
    br = feats[..., r : r + q]
    mem = feats[..., r + q : r + q + m]
    sc = feats[..., r + q + m :]
    parts = [
        embed["op_table"][opcodes],  # lookup-table embedding
        regs @ embed["w_reg"],
        br @ embed["w_br"],
        mem @ embed["w_mem"],
        sc @ embed["w_sc"],
    ]
    x = jnp.concatenate(parts, axis=-1)
    if use_pallas:
        b, t, c = x.shape
        flat = x.reshape(b * t, c)
        pad = (-flat.shape[0]) % embed_kernel.ROW_BLOCK
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
        y = embed_kernel.linear_relu(flat, embed["w_comb"], embed["b_comb"])
        return y[: b * t].reshape(b, t, cfg.d_model)
    return kref.linear_relu_ref(
        x.reshape(-1, cfg.concat_dim), embed["w_comb"], embed["b_comb"]
    ).reshape(*x.shape[:-1], cfg.d_model)


def forward(params, opcodes, feats, cfg: ModelConfig, *, use_pallas=False):
    """Full forward pass.

    Returns a dict of per-window predictions for the **last** (current)
    instruction: ``fetch``/``exec`` (log1p cycles, ``f32[B]``), ``branch``
    / ``icache`` / ``tlb`` logits (``f32[B]``) and ``access`` logits
    (``f32[B, 4]``).
    """
    x = embed_instructions(params["embed"], opcodes, feats, cfg, use_pallas=use_pallas)
    # Per-architecture adaptation projection (Figure 7c).
    x = x @ params["adapt"]["w_adapt"]
    x = _layernorm(x)

    p = params["pred"]
    b, t, d = x.shape
    h, dk = cfg.heads, cfg.dk

    def split_heads(y):
        return y.reshape(b, t, h, dk).transpose(0, 2, 1, 3)

    q = split_heads(x @ p["wq"])
    k = split_heads(x @ p["wk"])
    v = split_heads(x @ p["wv"])
    if use_pallas:
        o = attn_kernel.mha(q, k, v)
    else:
        o = kref.mha_ref(q, k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, d)
    x = _layernorm(x + o @ p["wo"])  # residual + norm

    # Current instruction = last window position.
    hcur = x[:, -1, :]
    g = jnp.maximum(hcur @ p["w_ff"] + p["b_ff"], 0.0)

    return {
        "fetch": (g @ p["w_fetch"] + p["b_fetch"])[:, 0],
        "exec": (g @ p["w_exec"] + p["b_exec"])[:, 0],
        "branch": (g @ p["w_branch"] + p["b_branch"])[:, 0],
        "access": g @ p["w_access"] + p["b_access"],
        "icache": (g @ p["w_icache"] + p["b_icache"])[:, 0],
        "tlb": (g @ p["w_tlb"] + p["b_tlb"])[:, 0],
    }


def _bce(logits, targets):
    # Stable binary cross entropy from logits.
    return jnp.mean(jnp.maximum(logits, 0) - logits * targets + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def loss_fn(params, opcodes, feats, labels, cfg: ModelConfig, *, use_pallas=False):
    """Combined multi-metric loss (per-metric losses merged with the
    configured linear ratios, per §4.2).

    Args:
      labels: ``f32[B, 6]`` rows in datagen layout.

    Returns:
      (scalar loss, dict of per-metric losses).
    """
    out = forward(params, opcodes, feats, cfg, use_pallas=use_pallas)
    # Latencies are regressed in *raw cycle* space: the distribution is
    # heavy-tailed (mispredict/mem-stall events carry most cycles) and a
    # log-space MSE would collapse predictions to the median, destroying
    # CPI reconstruction. The small weight rebalances the raw magnitudes.
    l_fetch = jnp.mean((out["fetch"] - labels[:, LBL_FETCH]) ** 2)
    l_exec = jnp.mean((out["exec"] - labels[:, LBL_EXEC]) ** 2)
    l_branch = _bce(out["branch"], labels[:, LBL_MISPRED])
    access_t = labels[:, LBL_ACCESS].astype(jnp.int32)
    logp = jax.nn.log_softmax(out["access"], axis=-1)
    l_access = -jnp.mean(jnp.take_along_axis(logp, access_t[:, None], axis=1))
    l_icache = _bce(out["icache"], labels[:, LBL_ICACHE])
    l_tlb = _bce(out["tlb"], labels[:, LBL_TLB])
    total = (
        cfg.w_fetch * l_fetch
        + cfg.w_exec * l_exec
        + cfg.w_branch * l_branch
        + cfg.w_access * l_access
        + cfg.w_icache * l_icache
        + cfg.w_tlb * l_tlb
    )
    return total, {
        "fetch": l_fetch,
        "exec": l_exec,
        "branch": l_branch,
        "access": l_access,
        "icache": l_icache,
        "tlb": l_tlb,
    }


@functools.partial(jax.jit, static_argnames=("cfg",))
def predict(params, opcodes, feats, cfg: ModelConfig):
    """Jitted inference entry point (jnp path, used by evaluation)."""
    return forward(params, opcodes, feats, cfg, use_pallas=False)


def export_fn(params, cfg: ModelConfig, *, use_pallas=True):
    """The function `aot.py` lowers: weights closed over as constants.

    Returns a tuple in the fixed artifact order (see DESIGN.md §4):
    ``(fetch, exec, branch, access, icache, tlb)``.
    """

    def fn(opcodes, feats):
        out = forward(params, opcodes, feats, cfg, use_pallas=use_pallas)
        return (
            out["fetch"],
            out["exec"],
            out["branch"],
            out["access"],
            out["icache"],
            out["tlb"],
        )

    return fn
