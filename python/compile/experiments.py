"""Build-time experiment drivers for the retraining-dependent figures.

Each subcommand regenerates one paper artifact that requires *training
sweeps* (the Rust `tao report` harness covers everything that only needs
the simulators + the exported artifacts):

* ``figure12a`` — accuracy vs memory-queue size Nm;
* ``figure12b`` — accuracy vs branch-history (Nb, Nq);
* ``figure13``  — epochs vs test error for Granite / GradNorm /
  Tao-w/o-embed / Tao;
* ``figure14``  — training-pair selection: random-k vs Euclidean vs
  Mahalanobis;
* ``table5``    — training time: scratch vs direct fine-tune vs shared
  embeddings + fine-tune;
* ``figure15``  — Tao-predicted MPKI across the L1D-size and branch
  predictor sweeps (fine-tuned per design from the saved shared
  embeddings).

Every run prints its table and writes ``reports/<name>.txt`` so the Rust
side (and EXPERIMENTS.md) can pick the results up. Instruction counts and
epoch budgets are scaled-down defaults; pass ``--scale`` to grow them.

Datasets for non-preset designs are produced by invoking the Rust
`tao` binary (datagen is Rust-side by design — one feature extractor).
"""

import argparse
import json
import os
import subprocess
import sys
import tempfile
import time

import jax
import numpy as np

from . import data as data_mod
from . import model as model_mod
from . import multiarch, optim, simnet
from . import train as train_mod

TAO_BIN = os.environ.get("TAO_BIN", "../target/release/tao")
TRAIN_BENCHES = ["dee", "rom", "nab", "lee"]
TEST_BENCHES = ["mcf", "xal", "wrf", "cac"]


def log(msg):
    print(f"exp: {msg}", flush=True)


class ReportFile:
    """Mirror lines to stdout and reports/<name>.txt."""

    def __init__(self, name):
        os.makedirs("../reports", exist_ok=True)
        self.f = open(f"../reports/{name}.txt", "w")

    def line(self, s=""):
        print(s, flush=True)
        self.f.write(s + "\n")

    def close(self):
        self.f.close()


def run_datagen(out_dir, *, insts, uarchs="a", split="all", nb=1024, nq=32, nm=64, seed=42):
    """Invoke the Rust datagen for arbitrary feature configs."""
    cmd = [
        TAO_BIN, "datagen", "--out", out_dir, "--insts", str(insts),
        "--uarchs", uarchs, "--split", split, "--nb", str(nb), "--nq", str(nq),
        "--nm", str(nm), "--seed", str(seed),
    ]
    subprocess.run(cmd, check=True, capture_output=True)


def default_cfg(meta, context=32, **kw):
    fc = meta["feature_config"]
    num_scalars = meta["feature_dim"] - meta["num_regs"] - fc["nq"] - fc["nm"]
    return model_mod.ModelConfig(
        num_opcodes=len(meta["opcode_vocab"]),
        num_regs=meta["num_regs"],
        nq=fc["nq"],
        nm=fc["nm"],
        num_scalars=num_scalars,
        context=context,
        **kw,
    )


def quick_train(data_dir, uarch, cfg, *, epochs, max_windows, seed=0, params=None, mask=None):
    """Train a fresh (or provided) model on one arch's train benches."""
    benches = data_mod.load_split(data_dir, uarch, TRAIN_BENCHES)
    sampler = data_mod.WindowSampler(benches, cfg.context, 256, seed=seed, max_windows=max_windows)
    if params is None:
        params = model_mod.init_params(jax.random.PRNGKey(seed), cfg)
    ac = optim.AdamConfig(decay_steps=epochs * max(len(sampler), 1))
    return train_mod.train(params, sampler, cfg, epochs=epochs, adam_cfg=ac, mask=mask)


def avg_test_error(params, data_dir, uarch, cfg, *, max_insts=20000, metric="cpi_error_pct"):
    errs = []
    for b in TEST_BENCHES:
        bench = data_mod.load_bench(data_dir, uarch, b)
        ev = train_mod.evaluate(params, bench, cfg, max_insts=max_insts)
        errs.append(ev[metric])
    return float(np.mean(errs))


# --------------------------------------------------------------------------
# Figure 12: feature-engineering hyperparameter sweeps
# --------------------------------------------------------------------------

def figure12a(args):
    rep = ReportFile("figure12a")
    rep.line("Figure 12a — simulation error vs memory context queue size Nm")
    sizes = [16, 32, 64, 128] if args.scale == 1 else [32, 64, 128, 256]
    for nm in sizes:
        with tempfile.TemporaryDirectory() as d:
            run_datagen(d, insts=args.insts, uarchs="a", nm=nm)
            meta = data_mod.load_meta(d)
            cfg = default_cfg(meta)
            res = quick_train(d, "uarch_a", cfg, epochs=args.epochs, max_windows=args.windows)
            err = avg_test_error(res.params, d, "uarch_a", cfg)
            rep.line(f"  Nm={nm:>4}: avg CPI error {err:6.2f}%  (loss {res.losses[-1]:.2f})")
    rep.line("(paper shape: error falls with Nm, flattens past 64)")
    rep.close()


def figure12b(args):
    rep = ReportFile("figure12b")
    rep.line("Figure 12b — branch MPKI error vs branch history (Nb, Nq)")
    combos = [(256, 8), (256, 16), (1024, 16), (1024, 32)]
    for nb, nq in combos:
        with tempfile.TemporaryDirectory() as d:
            run_datagen(d, insts=args.insts, uarchs="a", nb=nb, nq=nq)
            meta = data_mod.load_meta(d)
            cfg = default_cfg(meta)
            res = quick_train(d, "uarch_a", cfg, epochs=args.epochs, max_windows=args.windows)
            errs = []
            for b in TEST_BENCHES:
                bench = data_mod.load_bench(d, "uarch_a", b)
                ev = train_mod.evaluate(res.params, bench, cfg, max_insts=20000)
                t, p = ev["branch_mpki_truth"], ev["branch_mpki_pred"]
                errs.append(abs(p - t) / max(t, 1e-9) * 100 if t > 0 else abs(p - t))
            rep.line(f"  Nb={nb:>5}, Nq={nq:>3}: avg branch MPKI error {np.mean(errs):6.2f}%")
    rep.line("(paper: (1k, 32) is the knee)")
    rep.close()


# --------------------------------------------------------------------------
# Figure 13: gradient-combination schemes
# --------------------------------------------------------------------------

def figure13(args):
    rep = ReportFile("figure13")
    rep.line("Figure 13 — test error vs training epochs for the §4.3 schemes")
    meta = data_mod.load_meta(args.data)
    cfg = default_cfg(meta)
    samplers = {
        u: data_mod.WindowSampler(
            data_mod.load_split(args.data, u, TRAIN_BENCHES),
            cfg.context, 256, seed=0, max_windows=args.windows,
        )
        for u in ("uarch_a", "uarch_b")
    }

    def eval_fn(embed, per_arch):
        errs = []
        for u in ("uarch_a", "uarch_b"):
            params = {"embed": embed, **per_arch[u]}
            errs.append(avg_test_error(params, args.data, u, cfg, max_insts=8000))
        return float(np.mean(errs))

    histories = {}
    for scheme in ("granite", "gradnorm", "tao_noembed", "tao"):
        log(f"figure13: scheme {scheme}")
        result = multiarch.train_shared(
            samplers, cfg, scheme=scheme, epochs=args.epochs, eval_fn=eval_fn, log=log,
        )
        histories[scheme] = [h["test_error"] for h in result.history]
    rep.line(f"{'epoch':>6} | " + " | ".join(f"{s:>12}" for s in histories))
    for e in range(args.epochs):
        rep.line(
            f"{e + 1:>6} | "
            + " | ".join(f"{histories[s][e]:>11.2f}%" for s in histories)
        )
    rep.line("(paper shape: tao < gradnorm < tao_noembed ~ granite at convergence)")
    rep.close()


# --------------------------------------------------------------------------
# Figure 14: training-pair selection strategies
# --------------------------------------------------------------------------

def _mahalanobis_matrix(perfs):
    x = np.asarray(perfs)
    cov = np.cov(x.T) + np.eye(x.shape[1]) * 1e-9
    inv = np.linalg.inv(cov)
    n = len(x)
    d = np.zeros((n, n))
    for i in range(n):
        for j in range(n):
            diff = x[i] - x[j]
            d[i, j] = float(np.sqrt(max(diff @ inv @ diff, 0.0)))
    return d


def _characterize_from_labels(data_dir, uarch):
    """PerfVector (CPI, L1 miss rate, L2-ish rate, mispredict rate) from
    the datagen labels — the python-side equivalent of `tao dse`."""
    cpis, l1s, l2s, brs = [], [], [], []
    for b in TRAIN_BENCHES:
        bench = data_mod.load_bench(data_dir, uarch, b)
        lbl = bench.labels
        n = len(bench)
        cpis.append(bench.total_cycles / n)
        mem = lbl[:, model_mod.LBL_ACCESS] > 0
        l1s.append((lbl[:, model_mod.LBL_ACCESS] >= 2).sum() / max(mem.sum(), 1))
        l2s.append((lbl[:, model_mod.LBL_ACCESS] >= 3).sum() / max(mem.sum(), 1))
        brs.append(lbl[:, model_mod.LBL_MISPRED].mean())
    return [np.mean(cpis), np.mean(l1s), np.mean(l2s), np.mean(brs)]


def figure14(args):
    rep = ReportFile("figure14")
    rep.line("Figure 14 — training-pair selection strategy vs simulation error")
    # Sample designs from the Table 3 space via the Rust CLI datagen of
    # presets + sampled designs. We approximate the paper's 20-design
    # sample with the three presets + sampled extremes generated by
    # `tao dse`; here we use presets a/b/c plus re-seeded variants.
    names = ["uarch_a", "uarch_b", "uarch_c"]
    with tempfile.TemporaryDirectory() as d:
        run_datagen(d, insts=args.insts, uarchs="a,b,c", split="all")
        meta = data_mod.load_meta(d)
        cfg = default_cfg(meta)
        perfs = [_characterize_from_labels(d, u) for u in names]
        dmat = _mahalanobis_matrix(perfs)
        emat = np.linalg.norm(
            np.asarray(perfs)[:, None, :] - np.asarray(perfs)[None, :, :], axis=-1
        )
        rng = np.random.default_rng(0)

        def pair_for(strategy):
            if strategy == "random":
                i, j = rng.choice(len(names), size=2, replace=False)
                return int(i), int(j)
            m = dmat if strategy == "mahalanobis" else emat
            flat = np.unravel_index(np.argmax(m), m.shape)
            return int(flat[0]), int(flat[1])

        for strategy in ("random", "euclidean", "mahalanobis"):
            i, j = pair_for(strategy)
            samplers = {
                names[k]: data_mod.WindowSampler(
                    data_mod.load_split(d, names[k], TRAIN_BENCHES),
                    cfg.context, 256, seed=0, max_windows=args.windows,
                )
                for k in (i, j)
            }
            shared = multiarch.train_shared(
                samplers, cfg, scheme="tao", epochs=args.epochs, log=None
            )
            # Fine-tune on the held-out design (pick one not in the pair).
            held = [k for k in range(len(names)) if k not in (i, j)][0]
            ft_sampler = data_mod.WindowSampler(
                data_mod.load_split(d, names[held], TRAIN_BENCHES),
                cfg.context, 256, seed=0, max_windows=args.windows // 2,
            )
            donor = shared.per_arch[names[i]]["pred"]
            res = multiarch.finetune_unseen(
                shared.embed, donor, ft_sampler, cfg, epochs=max(args.epochs // 2, 1)
            )
            err = avg_test_error(res.params, d, names[held], cfg, max_insts=10000)
            rep.line(
                f"  {strategy:<12} pair=({names[i]},{names[j]}) held-out={names[held]}: "
                f"avg CPI error {err:6.2f}%"
            )
    rep.line("(paper shape: mahalanobis <= euclidean <= random)")
    rep.close()


# --------------------------------------------------------------------------
# Table 5: transfer-learning training time
# --------------------------------------------------------------------------

def table5(args):
    rep = ReportFile("table5")
    rep.line("Table 5 — training time to a fixed loss target (uarch_c)")
    meta = data_mod.load_meta(args.data)
    cfg = default_cfg(meta)
    target_loss = args.loss_target

    def train_until(params, sampler, mask=None, max_epochs=30):
        ac = optim.AdamConfig()
        step = train_mod.make_train_step(cfg, ac, mask=mask)
        opt_state = optim.init_state(params)
        t0 = time.perf_counter()
        import jax.numpy as jnp
        for epoch in range(max_epochs):
            losses = []
            for opcodes, feats, labels in sampler.epoch():
                params, opt_state, loss, _ = step(
                    params, opt_state, jnp.asarray(opcodes), jnp.asarray(feats), jnp.asarray(labels)
                )
                losses.append(float(loss))
            avg = float(np.mean(losses))
            if avg <= target_loss:
                return time.perf_counter() - t0, epoch + 1, avg
        return time.perf_counter() - t0, max_epochs, avg

    benches_c = data_mod.load_split(args.data, "uarch_c", TRAIN_BENCHES)
    full = data_mod.WindowSampler(benches_c, cfg.context, 256, seed=0, max_windows=args.windows)
    reduced = data_mod.WindowSampler(
        benches_c, cfg.context, 256, seed=0, max_windows=args.windows // 5
    )

    # 1. scratch
    t_scratch, e1, l1 = train_until(model_mod.init_params(jax.random.PRNGKey(0), cfg), full)
    rep.line(f"  scratch                         : {t_scratch:7.1f}s ({e1} epochs, loss {l1:.2f})")

    # 2. direct fine-tuning from a donor arch (uarch_a quick-trained)
    donor = quick_train(args.data, "uarch_a", cfg, epochs=2, max_windows=args.windows)
    t0 = time.perf_counter()
    t_direct, e2, l2 = train_until(jax.tree.map(np.copy, donor.params), full)
    rep.line(f"  direct fine-tuning              : {t_direct:7.1f}s ({e2} epochs, loss {l2:.2f})")

    # 3. shared embeddings + fine-tune (frozen embeddings, reduced data)
    npz = np.load(os.path.join(args.artifacts, "shared_embeddings.npz"))
    embed = {k.split("/", 1)[1]: npz[k] for k in npz.files if k.startswith("embed/")}
    pred = {k.split("/", 1)[1]: npz[k] for k in npz.files if k.startswith("pred/")}
    params = {
        "embed": embed,
        "adapt": model_mod.init_adapt_params(cfg),
        "pred": pred,
    }
    mask = optim.make_mask(params, lambda p: not p.startswith("embed"))
    t_shared, e3, l3 = train_until(params, reduced, mask=mask)
    rep.line(f"  shared embeddings + fine-tuning : {t_shared:7.1f}s ({e3} epochs, loss {l3:.2f})")
    rep.line(
        f"  speedup vs scratch: direct {t_scratch / max(t_direct, 1e-9):.1f}x, "
        f"shared {t_scratch / max(t_shared, 1e-9):.1f}x "
        "(paper: 56h -> 38h -> 1.9h, i.e. ~1.5x and ~29x)"
    )
    rep.close()


# --------------------------------------------------------------------------
# Figure 15: Tao-predicted DSE series
# --------------------------------------------------------------------------

def figure15(args):
    rep = ReportFile("figure15_tao")
    meta = data_mod.load_meta(args.data)
    cfg = default_cfg(meta)
    npz = np.load(os.path.join(args.artifacts, "shared_embeddings.npz"))
    embed = {k.split("/", 1)[1]: npz[k] for k in npz.files if k.startswith("embed/")}
    donor_pred = {k.split("/", 1)[1]: npz[k] for k in npz.files if k.startswith("pred/")}

    def finetuned_metrics(datadir, uarch):
        sampler = data_mod.WindowSampler(
            data_mod.load_split(datadir, uarch, TRAIN_BENCHES),
            cfg.context, 256, seed=0, max_windows=args.windows // 2,
        )
        res = multiarch.finetune_unseen(
            embed, donor_pred, sampler, cfg, epochs=max(args.epochs // 2, 1)
        )
        out = {}
        for metric in ("l1d_mpki", "branch_mpki"):
            preds, truths = [], []
            for b in TEST_BENCHES:
                bench = data_mod.load_bench(datadir, uarch, b)
                ev = train_mod.evaluate(res.params, bench, cfg, max_insts=15000)
                preds.append(ev[f"{metric}_pred"])
                truths.append(ev[f"{metric}_truth"])
            out[metric] = (float(np.mean(preds)), float(np.mean(truths)))
        return out

    # The sweeps vary one axis of uarch_b; Rust datagen only exposes the
    # presets, so we reuse preset data generated per design via the
    # `--uarchs` presets... For non-preset points we lean on the Rust
    # report for ground truth and fine-tune on the nearest preset data.
    # Here: evaluate Tao's predicted MPKI on the three presets (spanning
    # the L1D 16/32/64KB and Local/BiMode/Tournament points of the sweep).
    rep.line("Tao-predicted sweep points (fine-tuned per design, test-bench avg):")
    for uarch, label in (("uarch_a", "L1D 16KB / Local"),
                         ("uarch_b", "L1D 32KB / BiMode"),
                         ("uarch_c", "L1D 64KB / Tournament")):
        m = finetuned_metrics(args.data, uarch)
        (p_l1, t_l1), (p_br, t_br) = m["l1d_mpki"], m["branch_mpki"]
        rep.line(
            f"  {label:<24}: L1D MPKI pred {p_l1:7.2f} (truth {t_l1:7.2f}) | "
            f"branch MPKI pred {p_br:6.2f} (truth {t_br:6.2f})"
        )
    rep.line("(join with `tao report figure15` for the full ground-truth sweeps)")
    rep.close()


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("experiment", choices=[
        "figure12a", "figure12b", "figure13", "figure14", "table5", "figure15",
    ])
    ap.add_argument("--data", default="../data")
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--insts", type=int, default=15000)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--windows", type=int, default=15000)
    ap.add_argument("--scale", type=int, default=1)
    ap.add_argument("--loss-target", type=float, default=95.0)
    args = ap.parse_args(argv)
    t0 = time.perf_counter()
    globals()[args.experiment](args)
    log(f"{args.experiment} done in {time.perf_counter() - t0:.1f}s")


if __name__ == "__main__":
    main()
