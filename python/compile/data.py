"""Dataset loading and window batching for the build-time trainer.

Consumes the `.npy` arrays `tao datagen` writes (see
rust/src/datagen/mod.rs for the layout) and serves `[B, T]` /
`[B, T, F]` context windows: window *i* ends at instruction *i* — the
model predicts the last position, with the preceding ``T−1`` instructions
as context (paper §4.2, "a sequence of N+1 instructions as input").
"""

import dataclasses
import json
import os

import numpy as np


@dataclasses.dataclass
class BenchData:
    """Arrays for one (µarch, benchmark) pair."""

    name: str
    opcodes: np.ndarray  # i32 [M]
    features: np.ndarray  # f32 [M, F]
    labels: np.ndarray  # f32 [M, 6]
    total_cycles: int

    def __len__(self):
        return len(self.opcodes)


def load_meta(data_dir):
    """Parse data/meta.json."""
    with open(os.path.join(data_dir, "meta.json")) as f:
        return json.load(f)


def load_bench(data_dir, uarch, bench):
    """Load one (µarch, benchmark) dataset."""
    d = os.path.join(data_dir, uarch, bench)
    with open(os.path.join(d, "total_cycles.txt")) as f:
        total = int(f.read().strip())
    return BenchData(
        name=bench,
        opcodes=np.load(os.path.join(d, "opcodes.npy")),
        features=np.load(os.path.join(d, "features.npy")),
        labels=np.load(os.path.join(d, "labels.npy")),
        total_cycles=total,
    )


def load_split(data_dir, uarch, benches):
    """Load several benchmarks for one µarch."""
    return [load_bench(data_dir, uarch, b) for b in benches]


def window_batch(bench: BenchData, idx, context):
    """Gather windows ending at each index in `idx`.

    Returns (opcodes [B,T], features [B,T,F], labels [B,6]) — labels are
    those of the final (current) instruction.
    """
    idx = np.asarray(idx)
    assert idx.min() >= context - 1, "window would underrun the trace"
    offsets = np.arange(-(context - 1), 1)
    gather = idx[:, None] + offsets[None, :]  # [B, T]
    return (
        bench.opcodes[gather],
        bench.features[gather],
        bench.labels[idx],
    )


class WindowSampler:
    """Shuffled epoch iterator over windows of several benchmarks."""

    def __init__(self, benches, context, batch, seed=0, max_windows=None):
        self.benches = benches
        self.context = context
        self.batch = batch
        self.rng = np.random.default_rng(seed)
        # Global index: (bench_idx, instruction_idx).
        pairs = []
        for bi, b in enumerate(benches):
            n = len(b)
            if n >= context:
                pairs.append(
                    np.stack(
                        [np.full(n - context + 1, bi), np.arange(context - 1, n)],
                        axis=1,
                    )
                )
        self.index = np.concatenate(pairs) if pairs else np.zeros((0, 2), np.int64)
        if max_windows is not None and len(self.index) > max_windows:
            sel = self.rng.choice(len(self.index), size=max_windows, replace=False)
            self.index = self.index[sel]

    def __len__(self):
        return len(self.index) // self.batch

    def epoch(self):
        """Yield (opcodes, features, labels) batches, reshuffled."""
        order = self.rng.permutation(len(self.index))
        for start in range(0, len(order) - self.batch + 1, self.batch):
            chunk = self.index[order[start : start + self.batch]]
            # Group by benchmark for contiguous gathers.
            ops, feats, labels = [], [], []
            for bi in np.unique(chunk[:, 0]):
                rows = chunk[chunk[:, 0] == bi, 1]
                o, f, l = window_batch(self.benches[bi], rows, self.context)
                ops.append(o)
                feats.append(f)
                labels.append(l)
            yield (
                np.concatenate(ops),
                np.concatenate(feats),
                np.concatenate(labels),
            )


def sequential_windows(bench: BenchData, context, batch):
    """Deterministic, in-order window batches over a full benchmark
    (evaluation / CPI reconstruction). The first ``context−1``
    instructions are emitted with left-padded (repeated-first) context."""
    n = len(bench)
    for start in range(0, n, batch):
        idx = np.arange(start, min(start + batch, n))
        idx_clamped = np.maximum(idx, context - 1)
        o, f, l = window_batch(bench, idx_clamped, context)
        # For the warm-up rows, labels must still be the true rows.
        l = bench.labels[idx]
        yield idx, (o, f, l)
