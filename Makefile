# Convenience targets. Tier-1 verify is `cargo build --release && cargo test -q`.
#
# CI (.github/workflows/ci.yml) runs: build, test, fmt --check,
# clippy -D warnings, then `bench-smoke` + `bench-gate`. `make ci`
# reproduces the same gate locally. The bench gate compares the fresh
# BENCH_*.json against the committed snapshots in benches/baselines/
# (warn-only until 3 non-provisional snapshots exist, then fails on a
# >15% items/sec regression vs the per-case baseline median); use
# `make bench-baseline` after a trusted run to append a snapshot.

.PHONY: build test fmt-check clippy bench bench-smoke bench-serve chaos-smoke \
        metrics-smoke router-smoke bench-gate bench-baseline ci

# Peak-RSS budget shared by the RSS-gated smokes (matches CI).
RSS_BUDGET_KB ?= 655360

build:
	cargo build --release

test:
	cargo test -q

fmt-check:
	cargo fmt --all -- --check

clippy:
	cargo clippy --workspace --all-targets -- -D warnings

# Full benchmark sweep (prints to stdout). Includes the serve smoke so
# a following bench-gate finds all three BENCH_*.json reports.
bench:
	cargo bench --bench coordinator -- --json BENCH_coordinator.json
	cargo bench --bench features -- --json BENCH_features.json
	$(MAKE) bench-serve

# CI smoke benches: reduced counts, emits BENCH_coordinator.json,
# BENCH_features.json and BENCH_serve.json (via bench-serve) with
# instructions/sec + per-batch staging latency so successive PRs have a
# perf trajectory. BENCH_coordinator.json also records pipelined-vs-
# serial engine items/sec per worker count plus the stage/execute
# occupancy counters (pipeline_* metrics; bench-gate surfaces them).
bench-smoke:
	cargo bench --bench coordinator -- --smoke --json BENCH_coordinator.json
	cargo bench --bench features -- --smoke --json BENCH_features.json
	$(MAKE) bench-serve

# Serving smoke: start `tao serve` on an ephemeral port with the
# surrogate artifact set, replay a mixed scenario load (verifying every
# served result against the offline engine and that packed occupancy
# beats per-request occupancy), emit BENCH_serve.json, drain. Then
# measure the router-tier scale-up curve (1/2/4 workers behind
# `tao router`) into the same report: router_jobs_per_sec_{N}w plus
# router_scaleup_{N}w, which bench-gate warns on below 1.6x/doubling.
bench-serve: build
	d=$$(mktemp -d /tmp/tao-serve.XXXXXX); \
	target/release/tao serve --surrogate-dir $$d/artifacts \
	  --port-file $$d/port --admission-wait-ms 150 & \
	serve_pid=$$!; \
	target/release/tao loadgen --port-file $$d/port \
	  --json BENCH_serve.json --verify-models $$d/artifacts \
	  --assert-occupancy --shutdown; status=$$?; \
	if [ $$status -ne 0 ]; then kill $$serve_pid 2>/dev/null || true; fi; \
	wait $$serve_pid; serve_status=$$?; \
	rm -rf $$d; \
	if [ $$status -eq 0 ]; then status=$$serve_status; fi; \
	if [ $$status -eq 0 ]; then \
	  target/release/tao router-bench --fleets 1,2,4 \
	    --json BENCH_serve.json; status=$$?; \
	fi; \
	exit $$status

# Chaos smoke (mirrors CI's chaos-smoke job): a daemon with every
# server-side fault probe armed at low probability plus a journaled
# cache takes the two-round `loadgen --chaos` soak — every job must
# end typed, every success bit-identical to the offline engine.
chaos-smoke: build
	d=$$(mktemp -d /tmp/tao-chaos.XXXXXX); \
	TAO_FAULTS='chunk_decode=0.002,exec_panic=0.001,queue_stall=0.002,cache_torn_write=0.002' \
	target/release/tao serve --surrogate-dir $$d/artifacts \
	  --port-file $$d/port --cache-journal $$d/cache.tjr \
	  --admission-wait-ms 150 & \
	serve_pid=$$!; \
	target/release/tao loadgen --port-file $$d/port --chaos \
	  --jobs 24 --threads 8 --json BENCH_chaos.json \
	  --verify-models $$d/artifacts --shutdown; status=$$?; \
	if [ $$status -ne 0 ]; then kill $$serve_pid 2>/dev/null || true; fi; \
	wait $$serve_pid; serve_status=$$?; \
	rm -rf $$d; \
	if [ $$status -eq 0 ]; then status=$$serve_status; fi; \
	exit $$status

# Metrics smoke (mirrors CI's metrics-smoke job): boot a daemon, drive
# a small load, scrape /metrics, and assert (a) every expected metric
# family is present in the exposition and (b) the structural identity
# cache_hits + cache_misses == jobs_chunks holds exactly.
metrics-smoke: build
	d=$$(mktemp -d /tmp/tao-metrics.XXXXXX); \
	target/release/tao serve --surrogate-dir $$d/artifacts \
	  --port-file $$d/port --admission-wait-ms 150 --log-json & \
	serve_pid=$$!; \
	target/release/tao loadgen --port-file $$d/port \
	  --jobs 12 --threads 4 --progress-every 5; status=$$?; \
	if [ $$status -eq 0 ]; then \
	  addr=$$(cat $$d/port); \
	  curl -sf "http://$$addr/metrics" > $$d/metrics.txt; status=$$?; \
	fi; \
	if [ $$status -eq 0 ]; then \
	  for fam in tao_jobs_submitted_total tao_jobs_done_total tao_jobs_active \
	             tao_jobs_chunks_total tao_queue_depth tao_queue_wait_seconds \
	             tao_cache_hits_total tao_cache_misses_total tao_cache_entries \
	             tao_lane_jobs_total tao_lane_batches_total tao_lanes_down \
	             tao_packed_windows_total tao_batch_slots_total \
	             tao_request_seconds tao_stage_seconds \
	             tao_fault_checks_total tao_fault_fires_total \
	             tao_deadline_sweeps_total tao_errors_total \
	             tao_jobs_rejected_total; do \
	    grep -q "^$$fam" $$d/metrics.txt \
	      || { echo "metrics-smoke: family $$fam missing"; status=1; }; \
	  done; \
	fi; \
	if [ $$status -eq 0 ]; then \
	  awk ' \
	    /^tao_cache_hits_total/ { hits = $$2 } \
	    /^tao_cache_misses_total/ { misses = $$2 } \
	    /^tao_jobs_chunks_total/ { chunks = $$2 } \
	    END { \
	      if (hits + misses != chunks) { \
	        printf "metrics-smoke: hits %d + misses %d != chunks %d\n", hits, misses, chunks; \
	        exit 1; \
	      } \
	      printf "metrics-smoke: hits %d + misses %d == chunks %d\n", hits, misses, chunks; \
	    }' $$d/metrics.txt; status=$$?; \
	fi; \
	curl -sf -X POST "http://$$(cat $$d/port)/v1/shutdown" > /dev/null || true; \
	wait $$serve_pid; serve_status=$$?; \
	rm -rf $$d; \
	if [ $$status -eq 0 ]; then status=$$serve_status; fi; \
	exit $$status

# Router smoke (mirrors CI's router-smoke job): three workers behind a
# consistent-hash `tao router`, the router RSS-gated; one worker is
# kill -9'd while the load is in flight, so every job must survive via
# the failover walk (loadgen re-verifies each result against the
# offline engine), and the tao_router_* metric families must be live
# with a nonzero failover count.
router-smoke: build
	d=$$(mktemp -d /tmp/tao-router.XXXXXX); status=0; pids=""; router_pid=""; \
	for i in 1 2 3; do \
	  target/release/tao serve --surrogate-dir $$d/artifacts \
	    --port-file $$d/w$$i.port --cache-entries 512 \
	    --admission-wait-ms 150 2> $$d/w$$i.log & \
	  pids="$$pids $$!"; \
	  for _ in $$(seq 1 150); do test -s $$d/w$$i.port && break; sleep 0.2; done; \
	  test -s $$d/w$$i.port \
	    || { echo "router-smoke: worker $$i never bound"; cat $$d/w$$i.log; status=1; }; \
	done; \
	victim=$$(echo $$pids | awk '{print $$2}'); \
	if [ $$status -eq 0 ]; then \
	  /usr/bin/time -v target/release/tao router \
	    --workers $$(cat $$d/w1.port),$$(cat $$d/w2.port),$$(cat $$d/w3.port) \
	    --port-file $$d/router.port --health-interval-ms 100 \
	    2> $$d/time-router.log & \
	  router_pid=$$!; \
	  for _ in $$(seq 1 150); do test -s $$d/router.port && break; sleep 0.2; done; \
	  test -s $$d/router.port \
	    || { echo "router-smoke: router never bound"; cat $$d/time-router.log; status=1; }; \
	fi; \
	if [ $$status -eq 0 ]; then \
	  target/release/tao loadgen --port-file $$d/router.port \
	    --jobs 24 --threads 8 --insts 40000 \
	    --verify-models $$d/artifacts & lg=$$!; \
	  sleep 2; kill -9 $$victim 2>/dev/null || true; \
	  wait $$lg || { echo "router-smoke: loadgen failed"; status=1; }; \
	fi; \
	if [ $$status -eq 0 ]; then \
	  curl -sf "http://$$(cat $$d/router.port)/metrics" > $$d/metrics.txt \
	    || { echo "router-smoke: /metrics scrape failed"; status=1; }; \
	fi; \
	if [ $$status -eq 0 ]; then \
	  for fam in tao_router_forwards_total tao_router_failovers_total \
	             tao_router_workers_live tao_router_workers_known \
	             tao_router_request_seconds; do \
	    grep -q "^$$fam" $$d/metrics.txt \
	      || { echo "router-smoke: family $$fam missing"; status=1; }; \
	  done; \
	fi; \
	if [ $$status -eq 0 ]; then \
	  awk '/^tao_router_failovers_total/ { n += $$2 } \
	    END { if (n > 0) { printf "router-smoke: %d failovers\n", n; exit 0 } \
	          print "router-smoke: no failovers recorded"; exit 1 }' \
	    $$d/metrics.txt || status=1; \
	  awk '/^tao_router_workers_live/ \
	    { print "router-smoke: workers_live", $$2 }' $$d/metrics.txt; \
	fi; \
	if [ -n "$$router_pid" ]; then \
	  curl -sf -X POST "http://$$(cat $$d/router.port)/v1/shutdown" \
	    > /dev/null 2>&1 || true; \
	  wait $$router_pid || true; \
	  rss_kb=$$(grep 'Maximum resident set size' $$d/time-router.log \
	    | awk '{print $$NF}'); \
	  echo "router-smoke: router peak RSS $$rss_kb KB (budget $(RSS_BUDGET_KB) KB)"; \
	  if [ $$status -eq 0 ]; then \
	    test "$$rss_kb" -le "$(RSS_BUDGET_KB)" \
	      || { echo "router-smoke: RSS over budget"; status=1; }; \
	  fi; \
	fi; \
	for p in $$pids; do kill $$p 2>/dev/null || true; done; \
	for p in $$pids; do wait $$p || true; done; \
	rm -rf $$d; \
	exit $$status

# Gate the current BENCH_*.json against benches/baselines/.
bench-gate:
	cargo run --release --bin bench_gate -- \
	  BENCH_coordinator.json BENCH_features.json BENCH_serve.json \
	  --baselines benches/baselines

# Snapshot the current BENCH_*.json files as the next numbered baseline
# (commit the result to extend the trajectory).
bench-baseline:
	@last=$$(ls benches/baselines 2>/dev/null \
	  | sed -n 's/^\([0-9][0-9]*\)-BENCH_.*/\1/p' | sort -n | tail -1 | sed 's/^0*//'); \
	next=$$(printf '%04d' $$(( $${last:-0} + 1 ))); \
	for f in BENCH_coordinator.json BENCH_features.json BENCH_serve.json; do \
	  if [ -f $$f ]; then cp $$f benches/baselines/$$next-$$f; echo "baseline $$next-$$f"; fi; \
	done

# The full local CI gate. Steps run as sub-makes inside one recipe so
# the ordering (build → ... → bench-smoke → bench-gate) holds even
# under `make -jN`.
ci:
	$(MAKE) build
	$(MAKE) test
	$(MAKE) fmt-check
	$(MAKE) clippy
	$(MAKE) bench-smoke
	$(MAKE) metrics-smoke
	$(MAKE) router-smoke
	$(MAKE) bench-gate
