# Convenience targets. Tier-1 verify is `cargo build --release && cargo test -q`.

.PHONY: build test bench bench-smoke

build:
	cargo build --release

test:
	cargo test -q

# Full benchmark sweep (prints to stdout).
bench:
	cargo bench --bench coordinator -- --json BENCH_coordinator.json
	cargo bench --bench features -- --json BENCH_features.json

# CI smoke benches: reduced counts, emits BENCH_coordinator.json (and
# BENCH_features.json) with instructions/sec + per-batch staging
# latency so successive PRs have a perf trajectory.
bench-smoke:
	cargo bench --bench coordinator -- --smoke --json BENCH_coordinator.json
	cargo bench --bench features -- --smoke --json BENCH_features.json
