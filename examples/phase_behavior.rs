//! Phase-level behaviour (paper §5.3 / Figure 11).
//!
//! ```text
//! cargo run --release --example phase_behavior
//! ```
//!
//! Replays each test benchmark through the detailed simulator on µArch A
//! and prints the windowed CPI / L1D-MPKI / branch-MPKI series — the
//! ground-truth side of Figure 11. If the Tao artifact exists, the same
//! stream is also pushed through the DL model and both series are shown
//! side by side.

use std::path::Path;
use tao_sim::coordinator::engine;
use tao_sim::dataset;
use tao_sim::detailed::DetailedSim;
use tao_sim::functional::FunctionalSim;
use tao_sim::runtime::Session;
use tao_sim::stats::PhaseSeries;
use tao_sim::uarch::UarchConfig;
use tao_sim::workloads;

fn main() -> anyhow::Result<()> {
    let insts = 40_000;
    let window = 5_000;
    let cfg = UarchConfig::uarch_a();
    let artifact = Path::new("artifacts/tao_uarch_a.hlo.txt");
    let mut session = artifact
        .exists()
        .then(|| Session::load(artifact))
        .transpose()?;

    for w in workloads::testing() {
        let program = w.build(42);
        let (det, _) = DetailedSim::new(&program, &cfg).run(insts);
        let adj = dataset::adjust(&det);
        let mut truth = PhaseSeries::new(window);
        for s in &adj.samples {
            truth.push(
                s.labels.fetch_latency as f64,
                s.labels.branch_mispred,
                s.labels.access_level.is_l1_miss(),
                s.labels.icache_miss,
                s.labels.tlb_miss,
            );
        }
        truth.finish();

        let pred = match &mut session {
            Some(sess) => {
                let functional = FunctionalSim::new(&program).run(insts);
                engine::simulate_records(sess, &functional.records, None, Some(window))?.phase
            }
            None => None,
        };

        println!("== {} ==", w.name);
        println!(
            "{:>4} | {:>8} {:>8} | {:>8} {:>8} | {:>8} {:>8}",
            "win", "CPI", "CPI^", "L1Dmpki", "L1D^", "bMPKI", "bMPKI^"
        );
        for (i, t) in truth.windows.iter().enumerate() {
            let p = pred.as_ref().and_then(|ph| ph.windows.get(i));
            println!(
                "{:>4} | {:>8.3} {:>8} | {:>8.2} {:>8} | {:>8.2} {:>8}",
                i,
                t.cpi(),
                p.map(|m| format!("{:.3}", m.cpi())).unwrap_or_else(|| "-".into()),
                t.l1d_mpki(),
                p.map(|m| format!("{:.2}", m.l1d_mpki())).unwrap_or_else(|| "-".into()),
                t.branch_mpki(),
                p.map(|m| format!("{:.2}", m.branch_mpki())).unwrap_or_else(|| "-".into()),
            );
        }
    }
    if session.is_none() {
        println!("(run `make artifacts` to add the predicted series)");
    }
    Ok(())
}
