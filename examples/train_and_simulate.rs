//! End-to-end driver: regenerate data, (re)build artifacts, then simulate
//! — the full three-layer loop from a single entry point.
//!
//! ```text
//! cargo run --release --example train_and_simulate
//! ```
//!
//! This is the repository's end-to-end validation (recorded in
//! EXPERIMENTS.md): it produces training datasets with the Rust
//! substrate, shells out to the build-time Python trainer/exporter if the
//! artifacts are missing, and then runs the DL-based simulation of every
//! test benchmark on µArch A entirely from Rust, reporting the paper's
//! headline quantities (CPI error vs ground truth, throughput in MIPS).

use std::path::Path;
use tao_sim::coordinator::engine;
use tao_sim::datagen::{self, DatagenOptions};
use tao_sim::detailed::DetailedSim;
use tao_sim::functional::FunctionalSim;
use tao_sim::stats::{mean, simulation_error_percent};
use tao_sim::uarch::UarchConfig;
use tao_sim::workloads;

fn main() -> anyhow::Result<()> {
    let insts = 30_000u64;
    let artifact = Path::new("artifacts/tao_uarch_a.hlo.txt");

    // --- step 1: training data (Rust substrate) ---
    if !Path::new("data/meta.json").exists() {
        println!("[1/3] generating training datasets (data/)...");
        let uarchs = vec![
            UarchConfig::uarch_a(),
            UarchConfig::uarch_b(),
            UarchConfig::uarch_c(),
        ];
        datagen::run(
            Path::new("data"),
            &workloads::suite(),
            &uarchs,
            &DatagenOptions {
                instructions: insts,
                ..Default::default()
            },
        )?;
    } else {
        println!("[1/3] data/ present — skipping datagen");
    }

    // --- step 2: build-time training + AOT export (Python, once) ---
    if !artifact.exists() {
        println!("[2/3] training + exporting artifacts (python -m compile.aot)...");
        let status = std::process::Command::new("python")
            .args(["-m", "compile.aot", "--data", "../data", "--out", "../artifacts"])
            .current_dir("python")
            .status()?;
        anyhow::ensure!(status.success(), "aot export failed");
    } else {
        println!("[2/3] artifacts present — skipping training");
    }

    // --- step 3: request-path simulation (Rust only) ---
    println!("[3/3] DL-based simulation of the test benchmarks on uarch_a:");
    let cfg = UarchConfig::uarch_a();
    let mut errors = Vec::new();
    for w in workloads::testing() {
        let program = w.build(42);
        let functional = FunctionalSim::new(&program).run(insts);
        let (_, truth) = DetailedSim::new(&program, &cfg).stats_only().run(insts);
        let result = engine::simulate_parallel(artifact, &functional.records, 2, None)?;
        let err = simulation_error_percent(result.metrics.cpi(), truth.cpi());
        errors.push(err);
        println!(
            "  {:<4} CPI {:.3} vs truth {:.3} ({:>6.2}% err) | bMPKI {:>6.1} vs {:>6.1} | {:.3} MIPS",
            w.name,
            result.metrics.cpi(),
            truth.cpi(),
            err,
            result.metrics.branch_mpki(),
            truth.branch_mpki(),
            result.mips()
        );
    }
    println!("average CPI error: {:.2}%", mean(&errors));
    Ok(())
}
