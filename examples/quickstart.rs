//! Quickstart: the whole Tao pipeline on one benchmark, in one binary.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```
//!
//! 1. builds the `mcf` stand-in benchmark program;
//! 2. runs the functional simulator (microarchitecture-agnostic trace);
//! 3. runs the detailed out-of-order simulator on µArch A (ground truth);
//! 4. runs the §4.1 dataset-construction workflow and checks the Figure 2
//!    invariant;
//! 5. if `artifacts/tao_uarch_a.hlo.txt` exists (`make artifacts`), runs
//!    the DL-based simulation through PJRT and prints predicted vs true
//!    CPI / MPKIs.

use std::path::Path;
use tao_sim::coordinator::engine;
use tao_sim::dataset;
use tao_sim::detailed::DetailedSim;
use tao_sim::functional::FunctionalSim;
use tao_sim::stats::simulation_error_percent;
use tao_sim::uarch::UarchConfig;
use tao_sim::workloads;

fn main() -> anyhow::Result<()> {
    let insts = 50_000;
    let workload = workloads::by_name("mcf").expect("mcf in suite");
    let program = workload.build(42);
    println!("benchmark: {} ({})", workload.name, workload.description);

    // --- functional trace (reusable across microarchitectures) ---
    let t0 = std::time::Instant::now();
    let functional = FunctionalSim::new(&program).run(insts);
    println!(
        "functional trace: {} instructions in {:.2?}",
        functional.records.len(),
        t0.elapsed()
    );

    // --- detailed ground truth on µArch A ---
    let cfg = UarchConfig::uarch_a();
    let t0 = std::time::Instant::now();
    let (detailed, stats) = DetailedSim::new(&program, &cfg).run(insts);
    println!(
        "detailed O3 trace on {}: CPI {:.3}, branch MPKI {:.1}, L1D MPKI {:.1} ({:.2?})",
        cfg.name,
        stats.cpi(),
        stats.branch_mpki(),
        stats.l1d_mpki(),
        t0.elapsed()
    );
    println!(
        "  extra dynamic records: {} squashed speculative, {} pipeline-stall nops",
        detailed.squashed_count(),
        detailed.nop_count()
    );

    // --- §4.1 dataset construction ---
    let adjusted = dataset::adjust(&detailed);
    let aligned = dataset::align(&functional, adjusted)?;
    assert_eq!(aligned.reconstructed_cycles(), detailed.total_cycles);
    println!(
        "dataset construction: {} aligned samples; total-cycle invariant holds ({} cycles)",
        aligned.samples.len(),
        detailed.total_cycles
    );

    // --- DL-based simulation (needs `make artifacts`) ---
    let artifact = Path::new("artifacts/tao_uarch_a.hlo.txt");
    if artifact.exists() {
        let result = engine::simulate_parallel(artifact, &functional.records, 1, None)?;
        let m = result.metrics;
        println!(
            "Tao DL simulation: CPI {:.3} (truth {:.3}, error {:.2}%), branch MPKI {:.1}, L1D MPKI {:.1}",
            m.cpi(),
            stats.cpi(),
            simulation_error_percent(m.cpi(), stats.cpi()),
            m.branch_mpki(),
            m.l1d_mpki()
        );
        println!(
            "  {} batches in {:.2?} — {:.3} MIPS",
            result.batches,
            result.elapsed,
            result.mips()
        );
    } else {
        println!("(run `make artifacts` to enable the DL simulation step)");
    }
    Ok(())
}
