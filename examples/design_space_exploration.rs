//! Design-space exploration (paper §5.6 / Figure 15 workflow).
//!
//! ```text
//! cargo run --release --example design_space_exploration
//! ```
//!
//! Sweeps the L1 D-cache size and the branch predictor across the Table 3
//! ranges on the detailed simulator (the "gem5" side of Figure 15), then
//! samples the full 184,320-point space, characterizes each sample with
//! the §4.3 performance vector, and selects the two training designs by
//! maximum Mahalanobis distance (the Figure 8 workflow).

use tao_sim::detailed::DetailedSim;
use tao_sim::dse::{self, DesignSpace, SelectionStrategy};
use tao_sim::stats::mean;
use tao_sim::uarch::{CacheGeometry, PredictorKind, UarchConfig};
use tao_sim::util::Rng;
use tao_sim::workloads;

fn avg_over_tests(
    cfg: &UarchConfig,
    insts: u64,
    f: impl Fn(&tao_sim::detailed::SimStats) -> f64,
) -> f64 {
    let vals: Vec<f64> = workloads::testing()
        .iter()
        .map(|w| {
            let p = w.build(42);
            let (_, s) = DetailedSim::new(&p, cfg).stats_only().run(insts);
            f(&s)
        })
        .collect();
    mean(&vals)
}

fn main() -> anyhow::Result<()> {
    let insts = 20_000;
    let base = UarchConfig::uarch_b();

    println!("== L1 D-cache size sweep (avg L1D MPKI over test benchmarks) ==");
    for size_kb in [16u64, 32, 64, 128] {
        let mut cfg = base.clone();
        cfg.l1d = CacheGeometry { size_bytes: size_kb << 10, assoc: cfg.l1d.assoc };
        let mpki = avg_over_tests(&cfg, insts, |s| s.l1d_mpki());
        println!("  {size_kb:>4} KB: {mpki:7.2} MPKI");
    }

    println!("== branch predictor sweep (avg branch MPKI over test benchmarks) ==");
    for bp in PredictorKind::ALL {
        let mut cfg = base.clone();
        cfg.predictor = bp;
        let mpki = avg_over_tests(&cfg, insts, |s| s.branch_mpki());
        println!("  {:<12}: {mpki:6.2} MPKI", bp.name());
    }

    println!("== training-pair selection over a random design sample (Figure 8) ==");
    let space = DesignSpace::table3();
    println!("  design space size: {} points", space.count());
    let mut rng = Rng::new(7);
    let sample = space.sample(6, &mut rng);
    let perfs: Vec<_> = sample
        .iter()
        .map(|cfg| {
            let p = tao_sim::reports::sim_reports::characterize(cfg, 5_000, 42);
            println!(
                "  {:<11} cpi={:.2} l1={:.0}% l2={:.0}% bp={:.0}%  [{}]",
                cfg.name,
                p.cpi,
                p.l1_miss_rate * 100.0,
                p.l2_miss_rate * 100.0,
                p.mispredict_rate * 100.0,
                cfg.summary()
            );
            p
        })
        .collect();
    let (i, j) = dse::select_pair(&perfs, SelectionStrategy::Mahalanobis, &mut rng);
    println!(
        "  selected training pair (max Mahalanobis distance): {} + {}",
        sample[i].name, sample[j].name
    );
    Ok(())
}
