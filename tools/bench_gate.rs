//! CI bench gate — compare fresh `BENCH_*.json` reports against the
//! committed snapshots in `benches/baselines/` (see
//! `tao_sim::util::benchgate` for the policy: warn-only until enough
//! non-provisional baselines exist, then fail on a >tolerance
//! instructions/sec regression).
//!
//! ```text
//! bench_gate BENCH_coordinator.json BENCH_features.json BENCH_serve.json \
//!     [--baselines DIR] [--tolerance 0.15] [--min-baselines 3]
//! ```
//!
//! `BENCH_serve.json` comes out of `make bench-serve` (`tao loadgen`
//! against a local `tao serve`): its cases carry simulated
//! instructions/sec per serving phase (solo, concurrent cold,
//! concurrent warm), so the same items/sec trajectory policy applies.
//!
//! Exit codes: 0 clean or warn-only, 1 enforced regression, 2 usage or
//! I/O error.

use anyhow::Result;
use std::path::PathBuf;
use tao_sim::cli::args::Args;
use tao_sim::util::benchgate::{check, GateConfig, GateOutcome};

fn main() {
    match run() {
        Ok(true) => {}
        Ok(false) => std::process::exit(1),
        Err(e) => {
            eprintln!("bench_gate: error: {e:#}");
            std::process::exit(2);
        }
    }
}

fn print_outcome(o: &GateOutcome, cfg: &GateConfig) {
    println!(
        "bench_gate: {} — {} case(s) compared, {} enforcing + {} provisional baseline(s)",
        o.bench, o.compared, o.baselines, o.provisional
    );
    // Pipelined-vs-serial and phase-sampling trajectories
    // (informational): speedups, occupancy counters, and the sampled
    // CPI error vs its declared bound the coordinator bench exports.
    let metric = |name: &str| {
        o.pipeline_metrics
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
    };
    let error_bound = metric("sampled_error_bound_pct");
    for (k, v) in &o.pipeline_metrics {
        let warn = if k.starts_with("pipeline_speedup") && *v < 1.0 {
            "  (WARN: pipelined below serial on this run)"
        } else if k == "sampled_speedup" && *v < 4.0 {
            "  (WARN: sampled replay below the 4x speedup target)"
        } else if k == "sampled_max_error_pct" && error_bound.is_some_and(|b| *v > b) {
            "  (WARN: sampled CPI error exceeds the declared bound)"
        } else if k == "telemetry_overhead_pct" && *v > 2.0 {
            "  (WARN: armed telemetry costs more than the 2% budget)"
        } else if k == "router_scaleup_2w" && *v < 1.6 {
            "  (WARN: 2-worker router scale-up below the 1.6x/doubling floor)"
        } else if k == "router_scaleup_4w" && *v < 2.56 {
            "  (WARN: 4-worker router scale-up below the 2.56x floor, 1.6x/doubling)"
        } else {
            ""
        };
        println!("  {k}: {v:.3}{warn}");
    }
    for f in &o.regressions {
        println!(
            "  REGRESSION {}: {:.3e} items/s vs baseline median {:.3e} (-{:.1}%, tolerance {:.0}%)",
            f.case,
            f.current,
            f.reference,
            f.drop_percent(),
            cfg.tolerance * 100.0
        );
    }
    if o.failed(cfg) {
        println!("  gate: FAIL");
    } else if !o.regressions.is_empty() {
        println!(
            "  gate: warn-only ({} enforcing baseline(s) < {}) — would fail once enough accrue",
            o.baselines, cfg.min_baselines
        );
    } else {
        println!("  gate: clean");
    }
}

fn run() -> Result<bool> {
    let mut args = Args::new(std::env::args().skip(1).collect());
    let mut reports = Vec::new();
    while let Some(p) = args.next_positional() {
        reports.push(PathBuf::from(p));
    }
    let baselines: PathBuf = args
        .opt_value("--baselines")?
        .unwrap_or_else(|| "benches/baselines".into())
        .into();
    let tolerance: f64 = args.opt_parse("--tolerance")?.unwrap_or(0.15);
    let min_baselines: usize = args.opt_parse("--min-baselines")?.unwrap_or(3);
    args.finish()?;
    anyhow::ensure!(
        !reports.is_empty(),
        "usage: bench_gate <BENCH_*.json>... [--baselines DIR] [--tolerance T] [--min-baselines N]"
    );
    let cfg = GateConfig {
        tolerance,
        min_baselines,
    };
    let mut ok = true;
    for report in &reports {
        let outcome = check(report, &baselines, &cfg)?;
        print_outcome(&outcome, &cfg);
        ok &= !outcome.failed(&cfg);
    }
    Ok(ok)
}
